//! One client's protocol session: network selection and streamed evidence.
//!
//! A session is a pure state machine over protocol lines (the TCP layer in
//! [`crate::fleet::server`] just moves bytes), so the protocol is testable
//! without sockets. Per-session state is the selected network and an
//! evidence set built incrementally: `OBSERVE`/`RETRACT` stage deltas,
//! `COMMIT` applies them atomically, and every `QUERY` (and `MPE`) runs
//! under the committed evidence — a connection following a sensor feed
//! sends one small delta per reading instead of re-sending the full
//! evidence vector.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::fleet::registry::Compiled;
use crate::fleet::Fleet;
use crate::jt::evidence::Evidence;

/// Outcome of one protocol line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionReply {
    /// Single response line to write back.
    Line(String),
    /// Client asked to end the session.
    Quit,
}

/// Staged evidence change, applied in order by `COMMIT`.
enum Delta {
    /// Observe `var = state`.
    Set(usize, usize),
    /// Retract any observation of `var`.
    Clear(usize),
}

/// Upper bound on `BATCH <n>` so a typo cannot park a connection
/// collecting forever (and bound the dispatch allocation).
pub const MAX_BATCH_CASES: usize = 1024;

/// What an open batch computes per case: the posterior over one target
/// variable (sum-product), or the jointly most probable assignment
/// (max-product `MPE`). The literal target token `MPE` selects the
/// latter — checked before variable resolution, so a variable actually
/// named "MPE" is shadowed on the batch path (query it per-case via
/// `QUERY`).
enum BatchTarget {
    Posterior(usize),
    Mpe,
}

/// An in-progress `BATCH` collection: the model pinned at `BATCH` time,
/// target variable, expected case count, and the cases staged so far.
///
/// The collection is **self-contained**: `CASE` lines resolve against the
/// pinned model (not the session's possibly-evicted selection), so once a
/// batch is open every `CASE` is acked and the final reply is always
/// exactly n lines — the wire contract the cluster front's line counting
/// relies on. If the model was evicted or reloaded under the batch, the
/// final dispatch is refused and all n lines carry the error. A slot
/// whose `CASE` line failed to parse is kept as `Err` — it still consumes
/// its position (so client, cluster front, and backend all count the
/// same) and comes back as an `ERR` result line.
struct BatchCollect {
    net: String,
    model: Compiled,
    target: BatchTarget,
    expect: usize,
    cases: Vec<std::result::Result<Evidence, String>>,
}

/// Per-connection protocol state.
pub struct Session {
    fleet: Arc<Fleet>,
    current: Option<(String, Compiled)>,
    committed: BTreeMap<usize, usize>,
    pending: Vec<Delta>,
    batch: Option<BatchCollect>,
}

impl Session {
    /// New session against a fleet; no network selected, no evidence.
    pub fn new(fleet: Arc<Fleet>) -> Self {
        Session { fleet, current: None, committed: BTreeMap::new(), pending: Vec::new(), batch: None }
    }

    /// Name of the selected network, if any.
    pub fn current_net(&self) -> Option<&str> {
        self.current.as_ref().map(|(name, _)| name.as_str())
    }

    /// The session's network, revalidated against the registry. If the
    /// model was evicted — or evicted and reloaded under the same name,
    /// where variable ids need not line up — the session's cached ids are
    /// stale and must not be used: the selection is dropped and the client
    /// told to re-`USE`. `Err` carries the full reply line.
    fn current_model(&mut self) -> std::result::Result<(String, Compiled), String> {
        let Some((name, model)) = self.current.clone() else {
            return Err("ERR no network selected (USE <net> first)".into());
        };
        match self.fleet.model(&name) {
            Some(live) if live.same(&model) => Ok((name, model)),
            stale => {
                self.current = None;
                self.committed.clear();
                self.pending.clear();
                if stale.is_some() {
                    Err(format!("ERR network {name:?} was reloaded; USE it again"))
                } else {
                    Err(format!("ERR network {name:?} was evicted; LOAD and USE it again"))
                }
            }
        }
    }

    /// Number of committed observations.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Handle one protocol line, producing one reply.
    pub fn handle(&mut self, line: &str) -> SessionReply {
        let line = line.trim();
        if line.is_empty() {
            return SessionReply::Line("ERR empty request".into());
        }
        let mut parts = line.splitn(2, ' ');
        let verb = parts.next().unwrap_or("");
        let rest = parts.next().unwrap_or("").trim();
        let verb = verb.to_ascii_uppercase();
        // any verb other than CASE aborts an in-progress batch collection
        // (QUIT included — the session ends anyway). The cluster front
        // mirrors this rule for its forwarded-verb accounting, and it
        // further relies on mid-collection acks being *deterministic*
        // ("OK batch …", "OK case i/n" — even for malformed cases, whose
        // errors surface as result lines): that is what lets a clean
        // front session replay a buffered batch prefix on a surviving
        // replica when the collecting backend dies mid-batch.
        if self.batch.is_some() && verb != "CASE" {
            self.batch = None;
        }
        let reply = match verb.as_str() {
            "QUIT" => return SessionReply::Quit,
            "LOAD" => self.cmd_load(rest),
            "LEARN" => self.cmd_learn(rest),
            "USE" => self.cmd_use(rest),
            "NETS" => self.cmd_nets(),
            "OBSERVE" => self.cmd_observe(rest),
            "RETRACT" => self.cmd_retract(rest),
            "COMMIT" => self.cmd_commit(),
            "QUERY" => self.cmd_query(rest),
            "MPE" => self.cmd_mpe(rest),
            "BATCH" => self.cmd_batch(rest),
            "CASE" => self.cmd_case(rest),
            "STATS" => self.fleet.stats_line(),
            "METRICS" => self.cmd_metrics(),
            "TRACE" => self.cmd_trace(rest),
            "PROFILE" => self.cmd_profile(rest),
            "PING" => format!("OK pong nets={}", self.fleet.loaded().len()),
            "EVICT" => self.cmd_evict(rest),
            other => format!("ERR unknown verb {other:?}"),
        };
        SessionReply::Line(reply)
    }

    fn cmd_load(&mut self, spec: &str) -> String {
        if spec.is_empty() {
            return "ERR usage: LOAD <net>".into();
        }
        match self.fleet.load(spec) {
            Ok(e) => {
                let mut reply = format!(
                    "OK loaded {} cliques={} entries={} compile_ms={} tier={}",
                    e.name,
                    e.cliques,
                    e.entries,
                    e.compile_time.as_millis(),
                    e.tier
                );
                if let Some(cost) = e.cost {
                    reply.push_str(&format!(" cost={cost:.3e}"));
                }
                reply
            }
            Err(e) => format!("ERR {e}"),
        }
    }

    /// `LEARN <name> <spec> <samples> <seed>`: sample from `<spec>`,
    /// learn structure + parameters (see [`crate::learn`]), and register
    /// the result as `<name>` — immediately servable via `USE <name>`.
    /// Sugar over loading the deterministic
    /// `learn:<name>:<samples>:<seed>:<spec>` spec, so re-learning the
    /// same verb anywhere (another backend, after an eviction) yields the
    /// bit-identical network.
    fn cmd_learn(&mut self, rest: &str) -> String {
        // the verb grammar lives on LearnSpec so the cluster front parses
        // identically; validation runs before any expensive resolve
        let parsed = match crate::learn::LearnSpec::from_verb_args(rest) {
            Ok(parsed) => parsed,
            Err(e) => return format!("ERR {e}"),
        };
        // compile-once with honest semantics (enforced by the registry):
        // repeating the exact spec is an idempotent cache hit, but a
        // resident name of DIFFERENT provenance comes back as a clean
        // refusal — silently serving the old net while the reply (and,
        // via the cluster front, the hand-off directory) claims the new
        // samples/seed would let failover re-learning change answers.
        match self.fleet.load(&parsed.to_spec()) {
            Ok(e) => format!(
                "OK learned {} from={} samples={} seed={} cliques={} entries={} compile_ms={}",
                e.name,
                parsed.base,
                parsed.samples,
                parsed.seed,
                e.cliques,
                e.entries,
                e.compile_time.as_millis()
            ),
            Err(e) => format!("ERR {e}"),
        }
    }

    fn cmd_use(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: USE <net>".into();
        }
        match self.fleet.model(name) {
            Some(model) => {
                let vars = model.net().n();
                // evidence is per-network AND per-model: ids don't transfer
                // across networks, nor across a reload of the same name.
                // Only a defensive re-USE of the very same model keeps the
                // session's evidence.
                let same_model = match &self.current {
                    Some((cur, cur_model)) => cur == name && cur_model.same(&model),
                    None => false,
                };
                self.current = Some((name.to_string(), model));
                if !same_model {
                    self.committed.clear();
                    self.pending.clear();
                }
                format!("OK using {name} vars={vars}")
            }
            None => format!("ERR not loaded: {name:?} (LOAD it first)"),
        }
    }

    /// Cluster hand-off: the front tier evicts a network from its old
    /// owner after re-homing it. Any session pinned to the evicted tree
    /// (this one included) gets the standard "evicted" error next verb.
    fn cmd_evict(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: EVICT <net>".into();
        }
        if self.fleet.evict(name) {
            format!("OK evicted {name}")
        } else {
            format!("ERR not loaded: {name:?}")
        }
    }

    fn cmd_nets(&self) -> String {
        let entries = self.fleet.loaded();
        let mut out = format!("OK nets={}", entries.len());
        for e in &entries {
            out.push_str(&format!(
                " {}[cliques={} entries={} compile_ms={} tier={}]",
                e.name,
                e.cliques,
                e.entries,
                e.compile_time.as_millis(),
                e.tier
            ));
        }
        out
    }

    fn cmd_observe(&mut self, rest: &str) -> String {
        let model = match self.current_model() {
            Ok((_, model)) => model,
            Err(reply) => return reply,
        };
        if rest.is_empty() {
            return "ERR usage: OBSERVE var=state [var=state ...]".into();
        }
        // validate the whole line before staging anything: a line is
        // atomic, so a typo can't half-apply
        let mut staged = Vec::new();
        for tok in rest.split_whitespace() {
            let Some((var, state)) = tok.split_once('=') else {
                return format!("ERR bad evidence token {tok:?} (want var=state)");
            };
            match model.net().state_id(var, state) {
                Ok((v, s)) => staged.push(Delta::Set(v, s)),
                Err(e) => return format!("ERR {e}"),
            }
        }
        let n = staged.len();
        self.pending.extend(staged);
        format!("OK staged {n} pending={}", self.pending.len())
    }

    fn cmd_retract(&mut self, rest: &str) -> String {
        let model = match self.current_model() {
            Ok((_, model)) => model,
            Err(reply) => return reply,
        };
        if rest.is_empty() {
            return "ERR usage: RETRACT var [var ...]".into();
        }
        let mut staged = Vec::new();
        for var in rest.split_whitespace() {
            match model.net().var_id(var) {
                Ok(v) => staged.push(Delta::Clear(v)),
                Err(e) => return format!("ERR {e}"),
            }
        }
        let n = staged.len();
        self.pending.extend(staged);
        format!("OK retracted {n} pending={}", self.pending.len())
    }

    fn cmd_commit(&mut self) -> String {
        let applied = self.pending.len();
        for delta in self.pending.drain(..) {
            match delta {
                Delta::Set(v, s) => {
                    self.committed.insert(v, s);
                }
                Delta::Clear(v) => {
                    self.committed.remove(&v);
                }
            }
        }
        format!("OK committed evidence={} applied={applied}", self.committed.len())
    }

    /// `BATCH <n> <target-var|MPE>`: open an n-case collection. The next
    /// `n` `CASE` lines stage one evidence case each; the n-th dispatches
    /// all of them as **one** shard job (one fused lane-parallel sweep
    /// with the batched engine) and its reply carries the n result lines
    /// — N evidence lines in, N result lines out. The literal target
    /// `MPE` runs max-product per case instead of a posterior.
    fn cmd_batch(&mut self, rest: &str) -> String {
        let (name, model) = match self.current_model() {
            Ok(current) => current,
            Err(reply) => return reply,
        };
        let mut parts = rest.split_whitespace();
        let (Some(n_text), Some(target), None) = (parts.next(), parts.next(), parts.next()) else {
            return "ERR usage: BATCH <n> <target-var|MPE>".into();
        };
        let n = match n_text.parse::<usize>() {
            Ok(n) if (1..=MAX_BATCH_CASES).contains(&n) => n,
            _ => return format!("ERR batch size must be 1..={MAX_BATCH_CASES} (got {n_text:?})"),
        };
        // the MPE sentinel is matched before variable resolution (see
        // `BatchTarget`) — and only in its literal uppercase spelling, so
        // lowercase state-of-a-variable names stay resolvable
        let t = if target == "MPE" {
            BatchTarget::Mpe
        } else {
            match model.net().var_id(target) {
                Ok(v) => BatchTarget::Posterior(v),
                Err(e) => return format!("ERR {e}"),
            }
        };
        self.batch = Some(BatchCollect { net: name, model, target: t, expect: n, cases: Vec::with_capacity(n) });
        format!("OK batch expect={n} target={target}")
    }

    /// One case of an open batch: committed evidence plus inline
    /// `var=state` tokens (inline wins), exactly like `QUERY`'s inline
    /// grammar without the target. A malformed line consumes its slot and
    /// becomes an `ERR` result — counts stay aligned on every tier.
    fn cmd_case(&mut self, rest: &str) -> String {
        let Some(collect) = self.batch.as_mut() else {
            return "ERR no batch in progress (BATCH <n> <target-var> first)".into();
        };
        // resolve against the model pinned at BATCH time — never the
        // session's (possibly evicted) selection — so the ack/result line
        // count is unconditional once a batch is open
        let parsed: std::result::Result<Evidence, String> = {
            let mut obs = self.committed.clone();
            let mut err = None;
            for tok in rest.split_whitespace() {
                let Some((var, state)) = tok.split_once('=') else {
                    err = Some(format!("bad evidence token {tok:?} (want var=state)"));
                    break;
                };
                match collect.model.net().state_id(var, state) {
                    Ok((id, s)) => {
                        obs.insert(id, s);
                    }
                    Err(e) => {
                        err = Some(e.to_string());
                        break;
                    }
                }
            }
            match err {
                None => Ok(Evidence::from_ids(obs.into_iter().collect())),
                Some(msg) => Err(msg),
            }
        };
        collect.cases.push(parsed);
        let staged = collect.cases.len();
        if staged < collect.expect {
            return format!("OK case {staged}/{}", collect.expect);
        }
        // final case: one dispatch, n reply lines (joined — the line
        // server writes them as n wire lines). The pinned model must still
        // be the registry's live model: running old variable ids against a
        // reloaded model would misapply evidence, so a stale pin turns
        // into n clean error lines instead.
        let collect = self.batch.take().expect("checked above");
        let live = self.fleet.model(&collect.net);
        let stale = match &live {
            Some(live) => !live.same(&collect.model),
            None => true,
        };
        if stale {
            let msg = format!("ERR network {:?} was evicted or reloaded during the batch; USE it again", collect.net);
            return vec![msg; collect.expect].join("\n");
        }
        let evs: Vec<Evidence> =
            collect.cases.iter().map(|c| c.clone().unwrap_or_else(|_| Evidence::none())).collect();
        let lines: Vec<String> = match collect.target {
            BatchTarget::Posterior(v) => match self.fleet.query_batch(&collect.net, evs) {
                Ok(results) => collect
                    .cases
                    .iter()
                    .zip(results)
                    .map(|(parsed, outcome)| match (parsed, outcome) {
                        (Err(msg), _) => format!("ERR {msg}"),
                        (Ok(_), Ok(post)) => {
                            crate::coordinator::server::format_ok_posterior(collect.model.net(), v, &post)
                        }
                        (Ok(_), Err(e)) => format!("ERR {e}"),
                    })
                    .collect(),
                Err(e) => (0..collect.expect).map(|_| format!("ERR {e}")).collect(),
            },
            BatchTarget::Mpe => match self.fleet.mpe_batch(&collect.net, evs) {
                Ok(results) => collect
                    .cases
                    .iter()
                    .zip(results)
                    .map(|(parsed, outcome)| match (parsed, outcome) {
                        (Err(msg), _) => format!("ERR {msg}"),
                        (Ok(_), Ok(res)) => {
                            crate::coordinator::server::format_ok_mpe(collect.model.net(), &res)
                        }
                        (Ok(_), Err(e)) => format!("ERR {e}"),
                    })
                    .collect(),
                Err(e) => (0..collect.expect).map(|_| format!("ERR {e}")).collect(),
            },
        };
        lines.join("\n")
    }

    /// `METRICS`: the Prometheus-style exposition as a counted block —
    /// header `OK metrics lines=<n>` followed by exactly n body lines (the
    /// line server writes the joined reply as n+1 wire lines), so any
    /// line-protocol client (the cluster front included) knows how much to
    /// read without a terminator convention.
    fn cmd_metrics(&self) -> String {
        let body = self.fleet.metrics_exposition();
        if body.is_empty() {
            return "OK metrics lines=0".into();
        }
        format!("OK metrics lines={}\n{body}", body.lines().count())
    }

    /// `TRACE on|off|last|q<n>`: per-query span recording. `on`/`off` flip
    /// the process-wide recorder (spans are captured on the shard worker
    /// threads that run the engines, so the toggle cannot be per-session);
    /// `last` returns the most recent completed trace as one line; a
    /// `q<digits>` argument looks a specific query up by the correlation
    /// id it was tagged with (the trailing `#<qid>` token on its
    /// QUERY/MPE line — minted by the cluster front). Only that exact
    /// shape is a lookup: every other argument stays a usage error.
    fn cmd_trace(&self, arg: &str) -> String {
        match arg.to_ascii_lowercase().as_str() {
            "on" => {
                crate::obs::trace::set_enabled(true);
                "OK trace on".into()
            }
            "off" => {
                crate::obs::trace::set_enabled(false);
                "OK trace off".into()
            }
            "last" => match crate::obs::trace::last() {
                Some(t) => format!("OK trace {}", t.render()),
                None => "ERR no trace recorded (TRACE on, then QUERY)".into(),
            },
            qid if qid.len() > 1 && qid.starts_with('q') && qid[1..].bytes().all(|b| b.is_ascii_digit()) => {
                match crate::obs::trace::find(qid) {
                    Some(t) => format!("OK trace {}", t.render()),
                    None => format!("ERR no trace recorded for qid {qid:?}"),
                }
            }
            _ => "ERR usage: TRACE <on|off|last|q<n>>".into(),
        }
    }

    /// `PROFILE [on|off]`: the pool parallelism profiler (see
    /// [`crate::obs::profile`]). `on` arms it process-wide and clears
    /// prior tallies, `off` disarms it; bare `PROFILE` returns the
    /// per-region report as a counted block (`OK profile lines=<n>`,
    /// mirroring `METRICS`), one line per pool region with per-worker
    /// busy/idle lanes, utilization, load-imbalance ratio, and
    /// barrier-wait share.
    fn cmd_profile(&self, arg: &str) -> String {
        match arg.to_ascii_lowercase().as_str() {
            "on" => {
                crate::obs::profile::set_armed(true);
                "OK profile on".into()
            }
            "off" => {
                crate::obs::profile::set_armed(false);
                "OK profile off".into()
            }
            "" => {
                let body = crate::obs::profile::render();
                if body.is_empty() {
                    return "OK profile lines=0".into();
                }
                format!("OK profile lines={}\n{body}", body.lines().count())
            }
            _ => "ERR usage: PROFILE [on|off]".into(),
        }
    }

    /// `MPE [| var=state …]`: the jointly most probable assignment under
    /// the committed evidence plus inline one-shot pairs (inline wins),
    /// exactly `QUERY`'s evidence grammar without a target — the answer
    /// assigns every variable. Exact tier only: the sampling tier has no
    /// junction tree to run a max-product sweep over.
    fn cmd_mpe(&mut self, rest: &str) -> String {
        let (rest, qid) = split_qid(rest);
        let (name, model) = match self.current_model() {
            Ok(current) => current,
            Err(reply) => return reply,
        };
        let pairs = match crate::coordinator::server::parse_mpe_args(rest) {
            Ok(pairs) => pairs,
            Err(msg) => return format!("ERR {msg}"),
        };
        let mut obs = self.committed.clone();
        for (var, state) in pairs {
            match model.net().state_id(var, state) {
                Ok((id, s)) => {
                    obs.insert(id, s);
                }
                Err(e) => return format!("ERR {e}"),
            }
        }
        let ev = Evidence::from_ids(obs.into_iter().collect());
        match self.fleet.mpe_tagged(&name, ev, qid) {
            Ok(res) => crate::coordinator::server::format_ok_mpe(model.net(), &res),
            Err(e) => format!("ERR {e}"),
        }
    }

    fn cmd_query(&mut self, rest: &str) -> String {
        let (rest, qid) = split_qid(rest);
        let (name, model) = match self.current_model() {
            Ok(current) => current,
            Err(reply) => return reply,
        };
        // same `target [| var=state …]` grammar and reply format as the
        // single-tree server — the helpers own the wire format
        let (target, pairs) = match crate::coordinator::server::parse_query_args(rest) {
            Ok(parsed) => parsed,
            Err(msg) => return format!("ERR {msg}"),
        };
        let v = match model.net().var_id(target) {
            Ok(v) => v,
            Err(e) => return format!("ERR {e}"),
        };
        // committed evidence plus inline one-shot pairs (inline wins)
        let mut obs = self.committed.clone();
        for (var, state) in pairs {
            match model.net().state_id(var, state) {
                Ok((id, s)) => {
                    obs.insert(id, s);
                }
                Err(e) => return format!("ERR {e}"),
            }
        }
        let ev = Evidence::from_ids(obs.into_iter().collect());
        match self.fleet.query_tagged(&name, ev, qid) {
            Ok(post) => crate::coordinator::server::format_ok_posterior(model.net(), v, &post),
            Err(e) => format!("ERR {e}"),
        }
    }
}

/// Split a trailing `#<qid>` correlation token off a `QUERY`/`MPE`
/// argument string. The cluster front appends one when tracing is armed;
/// `#` is invalid in every position of the existing grammar (targets, the
/// `|` separator, `var=state` pairs), so stripping the final token is
/// unambiguous and untagged clients can never collide with it. The shard
/// worker tags its trace root with the id (see
/// [`crate::obs::trace::tag_qid`]) so `TRACE <qid>` finds the query later.
fn split_qid(rest: &str) -> (&str, Option<String>) {
    let tail = rest.rsplit(char::is_whitespace).next().unwrap_or("");
    if tail.len() > 1 && tail.starts_with('#') {
        let head = rest[..rest.len() - tail.len()].trim_end();
        (head, Some(tail[1..].to_string()))
    } else {
        (rest, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};
    use crate::fleet::FleetConfig;

    fn session() -> Session {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 2,
            registry_capacity: 4,
            max_exact_cost: f64::INFINITY,
        }));
        Session::new(fleet)
    }

    fn line(s: &mut Session, input: &str) -> String {
        match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        }
    }

    #[test]
    fn load_use_query_flow() {
        let mut s = session();
        let r = line(&mut s, "LOAD asia");
        assert!(r.starts_with("OK loaded asia cliques=6"), "{r}");
        let r = line(&mut s, "USE asia");
        assert!(r.starts_with("OK using asia vars=8"), "{r}");
        let r = line(&mut s, "QUERY lung | smoke=yes");
        assert!(r.starts_with("OK yes=0.100000"), "{r}");
        assert_eq!(s.handle("quit"), SessionReply::Quit);
    }

    #[test]
    fn streamed_deltas_match_one_shot_evidence() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        let oneshot = line(&mut s, "QUERY lung | smoke=yes");

        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("OK staged 1 pending=1"));
        // staged but uncommitted deltas don't affect queries
        let before = line(&mut s, "QUERY lung");
        assert!(before.starts_with("OK yes=0.055000"), "{before}");
        assert!(line(&mut s, "COMMIT").starts_with("OK committed evidence=1 applied=1"));
        let streamed = line(&mut s, "QUERY lung");
        assert_eq!(streamed, oneshot);

        // retract and the prior answer comes back
        line(&mut s, "RETRACT smoke");
        line(&mut s, "COMMIT");
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
        assert_eq!(s.committed_len(), 0);
    }

    #[test]
    fn inline_evidence_overrides_committed() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // inline smoke=no wins over committed smoke=yes
        let r = line(&mut s, "QUERY lung | smoke=no");
        assert!(r.starts_with("OK yes=0.010000"), "{r}");
    }

    #[test]
    fn error_paths() {
        let mut s = session();
        assert!(line(&mut s, "LOAD no-such-net").starts_with("ERR unknown network"));
        assert!(line(&mut s, "USE asia").starts_with("ERR not loaded"));
        assert!(line(&mut s, "QUERY lung").starts_with("ERR no network selected"));
        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("ERR no network selected"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert!(line(&mut s, "OBSERVE Smoker=True").starts_with("ERR unknown variable"), "wrong-net var");
        assert!(line(&mut s, "OBSERVE smoke").starts_with("ERR bad evidence token"));
        assert!(line(&mut s, "OBSERVE smoke=bogus").starts_with("ERR unknown state"));
        assert!(line(&mut s, "RETRACT nosuch").starts_with("ERR unknown variable"));
        assert!(line(&mut s, "FROB x").starts_with("ERR unknown verb"));
        assert!(line(&mut s, "").starts_with("ERR empty request"));
        // nothing half-staged by the failed OBSERVE lines
        assert!(line(&mut s, "COMMIT").starts_with("OK committed evidence=0 applied=0"));
    }

    #[test]
    fn reselecting_the_same_network_keeps_evidence() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // a defensive re-USE of the current net must not wipe the session
        assert!(line(&mut s, "USE asia").starts_with("OK using asia"));
        assert_eq!(s.committed_len(), 1);
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.100000"));
    }

    #[test]
    fn use_resets_evidence_between_networks() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "LOAD cancer");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        assert_eq!(s.committed_len(), 1);
        let r = line(&mut s, "USE cancer");
        assert!(r.starts_with("OK using cancer vars=5"), "{r}");
        assert_eq!(s.committed_len(), 0);
        // cancer vars resolve now
        assert!(line(&mut s, "OBSERVE Smoker=True").starts_with("OK staged 1"));
        // asia vars no longer do
        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("ERR unknown variable"));
    }

    #[test]
    fn eviction_and_reload_invalidate_the_session() {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 1,
            registry_capacity: 1,
            max_exact_cost: f64::INFINITY,
        }));
        let mut s = Session::new(fleet);
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // capacity 1: loading cancer evicts asia out from under the session
        line(&mut s, "LOAD cancer");
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was evicted"), "{r}");
        // the session recovers by selecting a live network
        assert!(line(&mut s, "USE cancer").starts_with("OK using cancer"));
        let r = line(&mut s, "QUERY Cancer");
        assert!(r.starts_with("OK True="), "{r}");

        // reload-under-the-same-name: the cached ids may be stale, so the
        // session must be told to re-USE rather than mix old ids onto the
        // new tree
        line(&mut s, "LOAD asia"); // evicts cancer, compiles a fresh asia tree
        let r = line(&mut s, "OBSERVE Smoker=True");
        assert!(r.starts_with("ERR network \"cancer\" was evicted"), "{r}");
        line(&mut s, "USE asia");
        line(&mut s, "LOAD cancer"); // evicts the session's tree...
        line(&mut s, "LOAD asia"); // ...and reloads a new one under the name
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was reloaded"), "{r}");
        assert!(line(&mut s, "USE asia").starts_with("OK using asia"));
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
    }

    #[test]
    fn batch_verb_collects_n_cases_and_returns_n_lines() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        let want_smoke_yes = line(&mut s, "QUERY lung | smoke=yes");
        let want_smoke_no = line(&mut s, "QUERY lung | smoke=no");
        let want_prior = line(&mut s, "QUERY lung");

        assert_eq!(line(&mut s, "BATCH 3 lung"), "OK batch expect=3 target=lung");
        assert_eq!(line(&mut s, "CASE smoke=yes"), "OK case 1/3");
        assert_eq!(line(&mut s, "CASE smoke=no"), "OK case 2/3");
        let reply = line(&mut s, "CASE");
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines, vec![want_smoke_yes.as_str(), want_smoke_no.as_str(), want_prior.as_str()]);
        // the batch is closed: a stray CASE errors
        assert!(line(&mut s, "CASE").starts_with("ERR no batch in progress"));
    }

    #[test]
    fn batch_merges_committed_evidence_and_inline_wins() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        let want_yes = line(&mut s, "QUERY lung");
        let want_no = line(&mut s, "QUERY lung | smoke=no");
        line(&mut s, "BATCH 2 lung");
        line(&mut s, "CASE");
        let reply = line(&mut s, "CASE smoke=no");
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines, vec![want_yes.as_str(), want_no.as_str()]);
    }

    #[test]
    fn batch_bad_slots_and_impossible_cases_fail_alone() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "BATCH 3 lung");
        // a malformed case consumes its slot
        assert_eq!(line(&mut s, "CASE smoke"), "OK case 1/3");
        assert_eq!(line(&mut s, "CASE either=no lung=yes"), "OK case 2/3");
        let reply = line(&mut s, "CASE smoke=yes");
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("ERR bad evidence token"), "{}", lines[0]);
        assert!(lines[1].starts_with("ERR evidence is inconsistent"), "{}", lines[1]);
        assert!(lines[2].starts_with("OK yes=0.100000"), "{}", lines[2]);
    }

    #[test]
    fn batch_evicted_mid_collection_still_returns_n_lines() {
        // the batch pins its tree, so CASE lines keep acking even after
        // another session evicts the net; the final dispatch refuses the
        // stale pin with exactly n error lines — the wire contract the
        // cluster front's line counting depends on
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 1,
            registry_capacity: 1,
            max_exact_cost: f64::INFINITY,
        }));
        let mut a = Session::new(Arc::clone(&fleet));
        let mut b = Session::new(fleet);
        line(&mut a, "LOAD asia");
        line(&mut a, "USE asia");
        assert!(line(&mut a, "BATCH 3 lung").starts_with("OK batch expect=3"));
        assert_eq!(line(&mut a, "CASE smoke=yes"), "OK case 1/3");
        // capacity 1: session B's LOAD evicts asia out from under the batch
        assert!(line(&mut b, "LOAD cancer").starts_with("OK loaded cancer"));
        // the collection keeps counting against the pinned tree...
        assert_eq!(line(&mut a, "CASE smoke=no"), "OK case 2/3");
        // ...and the final dispatch yields n clean error lines
        let reply = line(&mut a, "CASE");
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 3);
        for l in &lines {
            assert!(l.starts_with("ERR network \"asia\" was evicted or reloaded"), "{l}");
        }
        // the session recovers on the net that displaced its tree
        assert!(line(&mut a, "USE cancer").starts_with("OK using cancer"));
        assert!(line(&mut a, "QUERY Cancer").starts_with("OK True="));
    }

    #[test]
    fn mpe_verb_uses_committed_evidence_and_inline_wins() {
        let mut s = session();
        assert!(line(&mut s, "MPE").starts_with("ERR no network selected"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        let prior = line(&mut s, "MPE");
        assert!(prior.starts_with("OK mpe logp=-"), "{prior}");
        // one var=state token per variable, all eight of asia's
        assert_eq!(prior.split_whitespace().count(), 2 + 8, "{prior}");
        let oneshot = line(&mut s, "MPE | smoke=yes");
        assert!(oneshot.contains(" smoke=yes"), "{oneshot}");
        // committed evidence reproduces the inline answer bit-for-bit
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        assert_eq!(line(&mut s, "MPE"), oneshot);
        // inline wins over committed, exactly like QUERY
        let flipped = line(&mut s, "MPE | smoke=no");
        assert!(flipped.contains(" smoke=no"), "{flipped}");
        assert!(line(&mut s, "MPE | either=no lung=yes").starts_with("ERR evidence is inconsistent"));
        assert!(line(&mut s, "MPE smoke=yes").starts_with("ERR usage: MPE"));
        assert!(line(&mut s, "MPE | smoke").starts_with("ERR bad evidence token"));
    }

    #[test]
    fn batch_mpe_collects_n_cases_and_returns_n_assignment_lines() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        let want_smoke_yes = line(&mut s, "MPE | smoke=yes");
        let want_prior = line(&mut s, "MPE");

        assert_eq!(line(&mut s, "BATCH 3 MPE"), "OK batch expect=3 target=MPE");
        assert_eq!(line(&mut s, "CASE smoke=yes"), "OK case 1/3");
        assert_eq!(line(&mut s, "CASE either=no lung=yes"), "OK case 2/3");
        let reply = line(&mut s, "CASE");
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines.len(), 3);
        // batched max-product matches the single-case verb bit-for-bit,
        // and an impossible slot fails alone
        assert_eq!(lines[0], want_smoke_yes.as_str());
        assert!(lines[1].starts_with("ERR evidence is inconsistent"), "{}", lines[1]);
        assert_eq!(lines[2], want_prior.as_str());
        // the batch is closed: a stray CASE errors
        assert!(line(&mut s, "CASE").starts_with("ERR no batch in progress"));
        // the sentinel is case-sensitive: lowercase resolves as a variable
        assert!(line(&mut s, "BATCH 2 mpe").starts_with("ERR unknown variable"));
    }

    #[test]
    fn batch_error_paths_and_abort_semantics() {
        let mut s = session();
        assert!(line(&mut s, "BATCH 2 lung").starts_with("ERR no network selected"));
        assert!(line(&mut s, "CASE").starts_with("ERR no batch in progress"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert!(line(&mut s, "BATCH").starts_with("ERR usage: BATCH"));
        assert!(line(&mut s, "BATCH 2").starts_with("ERR usage: BATCH"));
        assert!(line(&mut s, "BATCH 0 lung").starts_with("ERR batch size"));
        assert!(line(&mut s, "BATCH 9999 lung").starts_with("ERR batch size"));
        assert!(line(&mut s, "BATCH 2 nosuch").starts_with("ERR unknown variable"));
        // a non-CASE verb aborts an open batch
        line(&mut s, "BATCH 2 lung");
        line(&mut s, "CASE smoke=yes");
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
        assert!(line(&mut s, "CASE smoke=no").starts_with("ERR no batch in progress"));
    }

    #[test]
    fn learn_verb_registers_a_servable_net() {
        let mut s = session();
        let r = line(&mut s, "LEARN asia-l asia 3000 7");
        assert!(r.starts_with("OK learned asia-l from=asia samples=3000 seed=7"), "{r}");
        assert!(line(&mut s, "USE asia-l").starts_with("OK using asia-l vars=8"));
        let q = line(&mut s, "QUERY smoke");
        assert!(q.starts_with("OK yes=0."), "{q}");
        // the learned net shows up beside ordinary loads
        assert!(line(&mut s, "NETS").contains("asia-l[cliques="));
        // re-LEARNing the exact same spec is an idempotent cache hit...
        assert!(line(&mut s, "LEARN asia-l asia 3000 7").starts_with("OK learned asia-l"));
        // ...but the same name with different provenance is refused (the
        // old net must not be served under a reply claiming the new seed)
        let r = line(&mut s, "LEARN asia-l asia 3000 8");
        assert!(r.starts_with("ERR network \"asia-l\" is already resident"), "{r}");
        // evicting frees the name for an actual relearn
        assert_eq!(line(&mut s, "EVICT asia-l"), "OK evicted asia-l");
        assert!(line(&mut s, "LEARN asia-l asia 3000 8").starts_with("OK learned asia-l"));
    }

    #[test]
    fn learn_verb_error_paths() {
        let mut s = session();
        assert!(line(&mut s, "LEARN").starts_with("ERR usage: LEARN"));
        assert!(line(&mut s, "LEARN x asia 10").starts_with("ERR usage: LEARN"));
        assert!(line(&mut s, "LEARN x asia 10 1 extra").starts_with("ERR usage: LEARN"));
        assert!(line(&mut s, "LEARN x asia 0 1").starts_with("ERR learn spec sample count"));
        assert!(line(&mut s, "LEARN x asia ten 1").starts_with("ERR bad sample count"));
        assert!(line(&mut s, "LEARN x asia 10 z").starts_with("ERR bad seed"));
        assert!(line(&mut s, "LEARN x no-such-net 100 1").starts_with("ERR unknown network"));
    }

    #[test]
    fn ping_answers_with_resident_count() {
        let mut s = session();
        assert_eq!(line(&mut s, "PING"), "OK pong nets=0");
        line(&mut s, "LOAD asia");
        assert_eq!(line(&mut s, "ping"), "OK pong nets=1");
    }

    #[test]
    fn evict_is_a_clean_handoff_for_pinned_sessions() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        assert!(line(&mut s, "EVICT").starts_with("ERR usage: EVICT"));
        assert!(line(&mut s, "EVICT nosuch").starts_with("ERR not loaded"));
        assert_eq!(line(&mut s, "EVICT asia"), "OK evicted asia");
        // the pinned session learns on its next verb — no stale evidence
        // can be applied to a later reload under the same name
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was evicted"), "{r}");
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert_eq!(s.committed_len(), 0);
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
    }

    #[test]
    fn nets_lists_resident_networks() {
        let mut s = session();
        assert_eq!(line(&mut s, "NETS"), "OK nets=0");
        line(&mut s, "LOAD asia");
        line(&mut s, "LOAD cancer");
        let r = line(&mut s, "NETS");
        assert!(r.starts_with("OK nets=2 asia[cliques=6"), "{r}");
        assert!(r.contains(" cancer[cliques="), "{r}");
    }

    #[test]
    fn metrics_verb_returns_a_counted_exposition_block() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "QUERY lung");
        line(&mut s, "QUERY lung | smoke=yes");
        let reply = line(&mut s, "METRICS");
        let mut lines = reply.lines();
        let header = lines.next().unwrap();
        let body: Vec<&str> = lines.collect();
        let n: usize = header.strip_prefix("OK metrics lines=").expect(header).parse().unwrap();
        assert_eq!(n, body.len(), "{reply}");
        assert!(body.contains(&"fastbn_queries_total{net=\"asia\"} 2"), "{reply}");
        assert!(body.iter().any(|l| l.starts_with("# TYPE fastbn_query_latency_us histogram")), "{reply}");
        assert!(body.contains(&"fastbn_query_latency_us_count{net=\"asia\"} 2"), "{reply}");
    }

    #[test]
    fn trace_verb_toggles_and_replays() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut s = session();
        assert!(line(&mut s, "TRACE").starts_with("ERR usage: TRACE"));
        assert!(line(&mut s, "TRACE maybe").starts_with("ERR usage: TRACE"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert_eq!(line(&mut s, "TRACE on"), "OK trace on");
        line(&mut s, "QUERY lung");
        // the ring is process-wide (other tests may also be tracing), so
        // assert the reply shape, not a specific span tree
        let r = line(&mut s, "TRACE last");
        assert!(r.starts_with("OK trace total_us="), "{r}");
        assert_eq!(line(&mut s, "TRACE off"), "OK trace off");
    }

    #[test]
    fn profile_verb_arms_reports_and_disarms() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut s = session();
        assert!(line(&mut s, "PROFILE maybe").starts_with("ERR usage: PROFILE"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert_eq!(line(&mut s, "PROFILE on"), "OK profile on");
        line(&mut s, "QUERY lung");
        // the profiler store is process-wide (concurrent tests may be
        // driving pool regions), so assert the counted-block shape, not
        // specific regions
        let reply = line(&mut s, "PROFILE");
        let mut lines = reply.lines();
        let header = lines.next().unwrap();
        let body: Vec<&str> = lines.collect();
        let n: usize = header.strip_prefix("OK profile lines=").expect(header).parse().unwrap();
        assert_eq!(n, body.len(), "{reply}");
        for l in &body {
            assert!(l.starts_with("region="), "{l}");
        }
        assert_eq!(line(&mut s, "PROFILE off"), "OK profile off");
    }

    #[test]
    fn trace_qid_token_is_stripped_and_correlates() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert_eq!(line(&mut s, "TRACE on"), "OK trace on");
        // a trailing #<qid> token is correlation metadata, not evidence:
        // the reply is byte-identical to the untagged query's
        let plain = line(&mut s, "QUERY lung | smoke=yes");
        assert_eq!(line(&mut s, "QUERY lung | smoke=yes #q770001"), plain);
        let r = line(&mut s, "TRACE q770001");
        assert!(r.starts_with("OK trace total_us="), "{r}");
        assert!(r.ends_with(" qid=q770001"), "{r}");
        // MPE takes the token through the same path
        let mpe_plain = line(&mut s, "MPE | smoke=yes");
        assert_eq!(line(&mut s, "MPE | smoke=yes #q770002"), mpe_plain);
        let r = line(&mut s, "TRACE q770002");
        assert!(r.starts_with("OK trace total_us="), "{r}");
        // an unknown qid is a clean error; non-qid args stay usage errors
        assert!(line(&mut s, "TRACE q770999").starts_with("ERR no trace recorded for qid"));
        assert!(line(&mut s, "TRACE qabc").starts_with("ERR usage: TRACE"));
        assert_eq!(line(&mut s, "TRACE off"), "OK trace off");
    }

    #[test]
    fn stats_after_queries_reports_counts() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "QUERY lung");
        line(&mut s, "QUERY bronc");
        let r = line(&mut s, "STATS");
        assert!(r.contains("| asia queries=2 errors=0"), "{r}");
        assert!(r.contains("p50_us="), "{r}");
    }
}
