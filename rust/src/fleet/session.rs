//! One client's protocol session: network selection and streamed evidence.
//!
//! A session is a pure state machine over protocol lines (the TCP layer in
//! [`crate::fleet::server`] just moves bytes), so the protocol is testable
//! without sockets. Per-session state is the selected network and an
//! evidence set built incrementally: `OBSERVE`/`RETRACT` stage deltas,
//! `COMMIT` applies them atomically, and every `QUERY` runs under the
//! committed evidence — a connection following a sensor feed sends one
//! small delta per reading instead of re-sending the full evidence vector.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::fleet::Fleet;
use crate::jt::evidence::Evidence;
use crate::jt::tree::JunctionTree;

/// Outcome of one protocol line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionReply {
    /// Single response line to write back.
    Line(String),
    /// Client asked to end the session.
    Quit,
}

/// Staged evidence change, applied in order by `COMMIT`.
enum Delta {
    /// Observe `var = state`.
    Set(usize, usize),
    /// Retract any observation of `var`.
    Clear(usize),
}

/// Per-connection protocol state.
pub struct Session {
    fleet: Arc<Fleet>,
    current: Option<(String, Arc<JunctionTree>)>,
    committed: BTreeMap<usize, usize>,
    pending: Vec<Delta>,
}

impl Session {
    /// New session against a fleet; no network selected, no evidence.
    pub fn new(fleet: Arc<Fleet>) -> Self {
        Session { fleet, current: None, committed: BTreeMap::new(), pending: Vec::new() }
    }

    /// Name of the selected network, if any.
    pub fn current_net(&self) -> Option<&str> {
        self.current.as_ref().map(|(name, _)| name.as_str())
    }

    /// The session's network, revalidated against the registry. If the
    /// tree was evicted — or evicted and reloaded under the same name,
    /// where variable ids need not line up — the session's cached ids are
    /// stale and must not be used: the selection is dropped and the client
    /// told to re-`USE`. `Err` carries the full reply line.
    fn current_tree(&mut self) -> std::result::Result<(String, Arc<JunctionTree>), String> {
        let Some((name, jt)) = self.current.clone() else {
            return Err("ERR no network selected (USE <net> first)".into());
        };
        match self.fleet.tree(&name) {
            Some(live) if Arc::ptr_eq(&live, &jt) => Ok((name, jt)),
            stale => {
                self.current = None;
                self.committed.clear();
                self.pending.clear();
                if stale.is_some() {
                    Err(format!("ERR network {name:?} was reloaded; USE it again"))
                } else {
                    Err(format!("ERR network {name:?} was evicted; LOAD and USE it again"))
                }
            }
        }
    }

    /// Number of committed observations.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Handle one protocol line, producing one reply.
    pub fn handle(&mut self, line: &str) -> SessionReply {
        let line = line.trim();
        if line.is_empty() {
            return SessionReply::Line("ERR empty request".into());
        }
        let mut parts = line.splitn(2, ' ');
        let verb = parts.next().unwrap_or("");
        let rest = parts.next().unwrap_or("").trim();
        let reply = match verb.to_ascii_uppercase().as_str() {
            "QUIT" => return SessionReply::Quit,
            "LOAD" => self.cmd_load(rest),
            "USE" => self.cmd_use(rest),
            "NETS" => self.cmd_nets(),
            "OBSERVE" => self.cmd_observe(rest),
            "RETRACT" => self.cmd_retract(rest),
            "COMMIT" => self.cmd_commit(),
            "QUERY" => self.cmd_query(rest),
            "STATS" => self.fleet.stats_line(),
            "PING" => format!("OK pong nets={}", self.fleet.loaded().len()),
            "EVICT" => self.cmd_evict(rest),
            other => format!("ERR unknown verb {other:?}"),
        };
        SessionReply::Line(reply)
    }

    fn cmd_load(&mut self, spec: &str) -> String {
        if spec.is_empty() {
            return "ERR usage: LOAD <net>".into();
        }
        match self.fleet.load(spec) {
            Ok(e) => format!(
                "OK loaded {} cliques={} entries={} compile_ms={}",
                e.name,
                e.cliques,
                e.entries,
                e.compile_time.as_millis()
            ),
            Err(e) => format!("ERR {e}"),
        }
    }

    fn cmd_use(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: USE <net>".into();
        }
        match self.fleet.tree(name) {
            Some(jt) => {
                let vars = jt.net.n();
                // evidence is per-network AND per-tree: ids don't transfer
                // across networks, nor across a reload of the same name.
                // Only a defensive re-USE of the very same tree keeps the
                // session's evidence.
                let same_tree = match &self.current {
                    Some((cur, cur_jt)) => cur == name && Arc::ptr_eq(cur_jt, &jt),
                    None => false,
                };
                self.current = Some((name.to_string(), jt));
                if !same_tree {
                    self.committed.clear();
                    self.pending.clear();
                }
                format!("OK using {name} vars={vars}")
            }
            None => format!("ERR not loaded: {name:?} (LOAD it first)"),
        }
    }

    /// Cluster hand-off: the front tier evicts a network from its old
    /// owner after re-homing it. Any session pinned to the evicted tree
    /// (this one included) gets the standard "evicted" error next verb.
    fn cmd_evict(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: EVICT <net>".into();
        }
        if self.fleet.evict(name) {
            format!("OK evicted {name}")
        } else {
            format!("ERR not loaded: {name:?}")
        }
    }

    fn cmd_nets(&self) -> String {
        let entries = self.fleet.loaded();
        let mut out = format!("OK nets={}", entries.len());
        for e in &entries {
            out.push_str(&format!(
                " {}[cliques={} entries={} compile_ms={}]",
                e.name,
                e.cliques,
                e.entries,
                e.compile_time.as_millis()
            ));
        }
        out
    }

    fn cmd_observe(&mut self, rest: &str) -> String {
        let jt = match self.current_tree() {
            Ok((_, jt)) => jt,
            Err(reply) => return reply,
        };
        if rest.is_empty() {
            return "ERR usage: OBSERVE var=state [var=state ...]".into();
        }
        // validate the whole line before staging anything: a line is
        // atomic, so a typo can't half-apply
        let mut staged = Vec::new();
        for tok in rest.split_whitespace() {
            let Some((var, state)) = tok.split_once('=') else {
                return format!("ERR bad evidence token {tok:?} (want var=state)");
            };
            match jt.net.state_id(var, state) {
                Ok((v, s)) => staged.push(Delta::Set(v, s)),
                Err(e) => return format!("ERR {e}"),
            }
        }
        let n = staged.len();
        self.pending.extend(staged);
        format!("OK staged {n} pending={}", self.pending.len())
    }

    fn cmd_retract(&mut self, rest: &str) -> String {
        let jt = match self.current_tree() {
            Ok((_, jt)) => jt,
            Err(reply) => return reply,
        };
        if rest.is_empty() {
            return "ERR usage: RETRACT var [var ...]".into();
        }
        let mut staged = Vec::new();
        for var in rest.split_whitespace() {
            match jt.net.var_id(var) {
                Ok(v) => staged.push(Delta::Clear(v)),
                Err(e) => return format!("ERR {e}"),
            }
        }
        let n = staged.len();
        self.pending.extend(staged);
        format!("OK retracted {n} pending={}", self.pending.len())
    }

    fn cmd_commit(&mut self) -> String {
        let applied = self.pending.len();
        for delta in self.pending.drain(..) {
            match delta {
                Delta::Set(v, s) => {
                    self.committed.insert(v, s);
                }
                Delta::Clear(v) => {
                    self.committed.remove(&v);
                }
            }
        }
        format!("OK committed evidence={} applied={applied}", self.committed.len())
    }

    fn cmd_query(&mut self, rest: &str) -> String {
        let (name, jt) = match self.current_tree() {
            Ok(current) => current,
            Err(reply) => return reply,
        };
        // same `target [| var=state …]` grammar and reply format as the
        // single-tree server — the helpers own the wire format
        let (target, pairs) = match crate::coordinator::server::parse_query_args(rest) {
            Ok(parsed) => parsed,
            Err(msg) => return format!("ERR {msg}"),
        };
        let v = match jt.net.var_id(target) {
            Ok(v) => v,
            Err(e) => return format!("ERR {e}"),
        };
        // committed evidence plus inline one-shot pairs (inline wins)
        let mut obs = self.committed.clone();
        for (var, state) in pairs {
            match jt.net.state_id(var, state) {
                Ok((id, s)) => {
                    obs.insert(id, s);
                }
                Err(e) => return format!("ERR {e}"),
            }
        }
        let ev = Evidence::from_ids(obs.into_iter().collect());
        match self.fleet.query(&name, ev) {
            Ok(post) => crate::coordinator::server::format_ok_posterior(&jt.net, v, &post),
            Err(e) => format!("ERR {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};
    use crate::fleet::FleetConfig;

    fn session() -> Session {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 2,
            registry_capacity: 4,
        }));
        Session::new(fleet)
    }

    fn line(s: &mut Session, input: &str) -> String {
        match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        }
    }

    #[test]
    fn load_use_query_flow() {
        let mut s = session();
        let r = line(&mut s, "LOAD asia");
        assert!(r.starts_with("OK loaded asia cliques=6"), "{r}");
        let r = line(&mut s, "USE asia");
        assert!(r.starts_with("OK using asia vars=8"), "{r}");
        let r = line(&mut s, "QUERY lung | smoke=yes");
        assert!(r.starts_with("OK yes=0.100000"), "{r}");
        assert_eq!(s.handle("quit"), SessionReply::Quit);
    }

    #[test]
    fn streamed_deltas_match_one_shot_evidence() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        let oneshot = line(&mut s, "QUERY lung | smoke=yes");

        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("OK staged 1 pending=1"));
        // staged but uncommitted deltas don't affect queries
        let before = line(&mut s, "QUERY lung");
        assert!(before.starts_with("OK yes=0.055000"), "{before}");
        assert!(line(&mut s, "COMMIT").starts_with("OK committed evidence=1 applied=1"));
        let streamed = line(&mut s, "QUERY lung");
        assert_eq!(streamed, oneshot);

        // retract and the prior answer comes back
        line(&mut s, "RETRACT smoke");
        line(&mut s, "COMMIT");
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
        assert_eq!(s.committed_len(), 0);
    }

    #[test]
    fn inline_evidence_overrides_committed() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // inline smoke=no wins over committed smoke=yes
        let r = line(&mut s, "QUERY lung | smoke=no");
        assert!(r.starts_with("OK yes=0.010000"), "{r}");
    }

    #[test]
    fn error_paths() {
        let mut s = session();
        assert!(line(&mut s, "LOAD no-such-net").starts_with("ERR unknown network"));
        assert!(line(&mut s, "USE asia").starts_with("ERR not loaded"));
        assert!(line(&mut s, "QUERY lung").starts_with("ERR no network selected"));
        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("ERR no network selected"));
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert!(line(&mut s, "OBSERVE Smoker=True").starts_with("ERR unknown variable"), "wrong-net var");
        assert!(line(&mut s, "OBSERVE smoke").starts_with("ERR bad evidence token"));
        assert!(line(&mut s, "OBSERVE smoke=bogus").starts_with("ERR unknown state"));
        assert!(line(&mut s, "RETRACT nosuch").starts_with("ERR unknown variable"));
        assert!(line(&mut s, "FROB x").starts_with("ERR unknown verb"));
        assert!(line(&mut s, "").starts_with("ERR empty request"));
        // nothing half-staged by the failed OBSERVE lines
        assert!(line(&mut s, "COMMIT").starts_with("OK committed evidence=0 applied=0"));
    }

    #[test]
    fn reselecting_the_same_network_keeps_evidence() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // a defensive re-USE of the current net must not wipe the session
        assert!(line(&mut s, "USE asia").starts_with("OK using asia"));
        assert_eq!(s.committed_len(), 1);
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.100000"));
    }

    #[test]
    fn use_resets_evidence_between_networks() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "LOAD cancer");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        assert_eq!(s.committed_len(), 1);
        let r = line(&mut s, "USE cancer");
        assert!(r.starts_with("OK using cancer vars=5"), "{r}");
        assert_eq!(s.committed_len(), 0);
        // cancer vars resolve now
        assert!(line(&mut s, "OBSERVE Smoker=True").starts_with("OK staged 1"));
        // asia vars no longer do
        assert!(line(&mut s, "OBSERVE smoke=yes").starts_with("ERR unknown variable"));
    }

    #[test]
    fn eviction_and_reload_invalidate_the_session() {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 1,
            registry_capacity: 1,
        }));
        let mut s = Session::new(fleet);
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        // capacity 1: loading cancer evicts asia out from under the session
        line(&mut s, "LOAD cancer");
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was evicted"), "{r}");
        // the session recovers by selecting a live network
        assert!(line(&mut s, "USE cancer").starts_with("OK using cancer"));
        let r = line(&mut s, "QUERY Cancer");
        assert!(r.starts_with("OK True="), "{r}");

        // reload-under-the-same-name: the cached ids may be stale, so the
        // session must be told to re-USE rather than mix old ids onto the
        // new tree
        line(&mut s, "LOAD asia"); // evicts cancer, compiles a fresh asia tree
        let r = line(&mut s, "OBSERVE Smoker=True");
        assert!(r.starts_with("ERR network \"cancer\" was evicted"), "{r}");
        line(&mut s, "USE asia");
        line(&mut s, "LOAD cancer"); // evicts the session's tree...
        line(&mut s, "LOAD asia"); // ...and reloads a new one under the name
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was reloaded"), "{r}");
        assert!(line(&mut s, "USE asia").starts_with("OK using asia"));
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
    }

    #[test]
    fn ping_answers_with_resident_count() {
        let mut s = session();
        assert_eq!(line(&mut s, "PING"), "OK pong nets=0");
        line(&mut s, "LOAD asia");
        assert_eq!(line(&mut s, "ping"), "OK pong nets=1");
    }

    #[test]
    fn evict_is_a_clean_handoff_for_pinned_sessions() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "OBSERVE smoke=yes");
        line(&mut s, "COMMIT");
        assert!(line(&mut s, "EVICT").starts_with("ERR usage: EVICT"));
        assert!(line(&mut s, "EVICT nosuch").starts_with("ERR not loaded"));
        assert_eq!(line(&mut s, "EVICT asia"), "OK evicted asia");
        // the pinned session learns on its next verb — no stale evidence
        // can be applied to a later reload under the same name
        let r = line(&mut s, "QUERY lung");
        assert!(r.starts_with("ERR network \"asia\" was evicted"), "{r}");
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        assert_eq!(s.committed_len(), 0);
        assert!(line(&mut s, "QUERY lung").starts_with("OK yes=0.055000"));
    }

    #[test]
    fn nets_lists_resident_networks() {
        let mut s = session();
        assert_eq!(line(&mut s, "NETS"), "OK nets=0");
        line(&mut s, "LOAD asia");
        line(&mut s, "LOAD cancer");
        let r = line(&mut s, "NETS");
        assert!(r.starts_with("OK nets=2 asia[cliques=6"), "{r}");
        assert!(r.contains(" cancer[cliques="), "{r}");
    }

    #[test]
    fn stats_after_queries_reports_counts() {
        let mut s = session();
        line(&mut s, "LOAD asia");
        line(&mut s, "USE asia");
        line(&mut s, "QUERY lung");
        line(&mut s, "QUERY bronc");
        let r = line(&mut s, "STATS");
        assert!(r.contains("| asia queries=2 errors=0"), "{r}");
        assert!(r.contains("p50_us="), "{r}");
    }
}
