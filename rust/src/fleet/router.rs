//! Shard router: per-network engine-replica groups and query dispatch.
//!
//! Each loaded network owns a [`ShardGroup`] of `N` shards. A shard is a
//! dedicated worker thread that builds its engine *inside* the thread
//! (engines are not `Send` — see [`crate::engine::Engine`]) and reuses one
//! [`TreeState`] across every request it serves, so the per-request cost is
//! a state reset plus propagation, never an allocation or a tree compile.
//! An approximate-tier model (see [`Compiled`]) gets an
//! [`ApproxEngine`] replica per shard instead — same dispatch, same wire
//! surface, no junction tree anywhere in the path.
//!
//! Dispatch is round-robin refined by per-shard depth accounting: the
//! rotor picks the starting shard, then the least-loaded shard from there
//! wins — round-robin spread under uniform load, overflow routing around a
//! shard stuck on an expensive query under skewed load.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::approx::ApproxEngine;
use crate::engine::{Engine, EngineConfig, EngineKind};
use crate::fleet::registry::Compiled;
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::mpe::MpeResult;
use crate::jt::state::TreeState;
use crate::{Error, Result};

/// Where (and in what shape) a job's per-case results go: sum-product
/// posteriors for `QUERY`/`BATCH`, max-product assignments for `MPE`.
enum JobReply {
    Posteriors(mpsc::Sender<(Vec<Result<Posteriors>>, Duration)>),
    Mpe(mpsc::Sender<(Vec<Result<MpeResult>>, Duration)>),
}

struct Job {
    /// One or more evidence cases; a multi-case job runs through the
    /// engine's `infer_batch` / `mpe_batch` in **one shard dispatch** (the
    /// `BATCH` verb path — a single sweep with the batched engine).
    cases: Vec<Evidence>,
    reply: JobReply,
    /// Cluster-minted query id: the shard worker tags its trace root with
    /// it so `TRACE <qid>` can find this dispatch's span tree. `None` on
    /// every untagged path (direct fleet clients, batches).
    qid: Option<String>,
}

struct Shard {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    depth: Arc<AtomicUsize>,
}

/// The engine replicas serving one network.
pub struct ShardGroup {
    name: String,
    model: Compiled,
    shards: Vec<Shard>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    rotor: AtomicUsize,
}

impl ShardGroup {
    /// Spawn `n_shards` worker threads (clamped to ≥ 1) for `model`.
    ///
    /// Spawn failure (e.g. a process thread limit) is an error, not a
    /// panic — the fleet serializes loads under a mutex, and a panic here
    /// would poison it and wedge `LOAD` fleet-wide. Workers already
    /// spawned exit on their own once their senders drop.
    pub fn new(name: &str, model: Compiled, n_shards: usize, engine: EngineKind, cfg: &EngineConfig) -> Result<Self> {
        let n_shards = n_shards.max(1);
        let mut shards = Vec::with_capacity(n_shards);
        let mut workers = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let (tx, rx) = mpsc::channel::<Job>();
            let depth = Arc::new(AtomicUsize::new(0));
            let worker_model = model.clone();
            let worker_cfg = cfg.clone();
            let worker_depth = Arc::clone(&depth);
            let handle = std::thread::Builder::new()
                .name(format!("fleet-{name}-{i}"))
                .spawn(move || shard_worker(worker_model, engine, worker_cfg, rx, worker_depth))?;
            shards.push(Shard { tx: Mutex::new(Some(tx)), depth });
            workers.push(handle);
        }
        Ok(ShardGroup { name: name.to_string(), model, shards, workers: Mutex::new(workers), rotor: AtomicUsize::new(0) })
    }

    /// Network name this group serves.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The shared model (tree or approximate-tier network).
    pub fn model(&self) -> &Compiled {
        &self.model
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Current in-flight depth per shard (diagnostics and tests).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed)).collect()
    }

    /// Run one query on this group, blocking until its shard replies.
    ///
    /// Returns the posteriors and the shard-side service time (queue wait
    /// excluded from neither — the clock starts when the job is accepted).
    pub fn dispatch(&self, ev: Evidence) -> Result<(Posteriors, Duration)> {
        self.dispatch_tagged(ev, None)
    }

    /// [`ShardGroup::dispatch`] with an optional query id for trace
    /// correlation (see [`Job::qid`]).
    pub fn dispatch_tagged(&self, ev: Evidence, qid: Option<String>) -> Result<(Posteriors, Duration)> {
        let (mut results, service) = self.dispatch_cases(vec![ev], qid)?;
        results.pop().expect("one case in, one result out").map(|p| (p, service))
    }

    /// Run a multi-case batch as **one** shard dispatch: the shard worker
    /// feeds all cases to `Engine::infer_batch` (one fused sweep per
    /// engine-side chunk with the batched engine). Per-case failures come
    /// back in their slots; the outer `Err` is reserved for transport
    /// (shutdown, dead worker).
    pub fn dispatch_batch(&self, cases: Vec<Evidence>) -> Result<(Vec<Result<Posteriors>>, Duration)> {
        self.dispatch_cases(cases, None)
    }

    fn dispatch_cases(&self, cases: Vec<Evidence>, qid: Option<String>) -> Result<(Vec<Result<Posteriors>>, Duration)> {
        if cases.is_empty() {
            return Ok((Vec::new(), Duration::ZERO));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.enqueue(cases, JobReply::Posteriors(reply_tx), qid)?;
        match reply_rx.recv() {
            Ok((outcomes, service)) => Ok((outcomes, service)),
            Err(_) => Err(Error::msg(format!("shard worker for {:?} died", self.name))),
        }
    }

    /// Run one MPE query on this group, blocking until its shard replies.
    pub fn dispatch_mpe(&self, ev: Evidence) -> Result<(MpeResult, Duration)> {
        self.dispatch_mpe_tagged(ev, None)
    }

    /// [`ShardGroup::dispatch_mpe`] with an optional query id for trace
    /// correlation (see [`Job::qid`]).
    pub fn dispatch_mpe_tagged(&self, ev: Evidence, qid: Option<String>) -> Result<(MpeResult, Duration)> {
        let (mut results, service) = self.dispatch_mpe_cases(vec![ev], qid)?;
        results.pop().expect("one case in, one result out").map(|r| (r, service))
    }

    /// Run a multi-case MPE batch as **one** shard dispatch; the shard
    /// worker feeds all cases to `Engine::mpe_batch` (lane-parallel max
    /// sweeps with the batched engine). Per-case failures come back in
    /// their slots, exactly like [`ShardGroup::dispatch_batch`].
    pub fn dispatch_mpe_batch(&self, cases: Vec<Evidence>) -> Result<(Vec<Result<MpeResult>>, Duration)> {
        self.dispatch_mpe_cases(cases, None)
    }

    fn dispatch_mpe_cases(
        &self,
        cases: Vec<Evidence>,
        qid: Option<String>,
    ) -> Result<(Vec<Result<MpeResult>>, Duration)> {
        if cases.is_empty() {
            return Ok((Vec::new(), Duration::ZERO));
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.enqueue(cases, JobReply::Mpe(reply_tx), qid)?;
        match reply_rx.recv() {
            Ok((outcomes, service)) => Ok((outcomes, service)),
            Err(_) => Err(Error::msg(format!("shard worker for {:?} died", self.name))),
        }
    }

    /// Pick a shard (rotor start, then least depth from there) and hand it
    /// the job, accounting its depth.
    fn enqueue(&self, cases: Vec<Evidence>, reply: JobReply, qid: Option<String>) -> Result<()> {
        let start = self.rotor.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut best = start;
        let mut best_depth = self.shards[start].depth.load(Ordering::Relaxed);
        for k in 1..self.shards.len() {
            let i = (start + k) % self.shards.len();
            let d = self.shards[i].depth.load(Ordering::Relaxed);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        let shard = &self.shards[best];
        let tx = match shard.tx.lock().unwrap().as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(Error::msg(format!("network {:?} is shutting down", self.name))),
        };
        shard.depth.fetch_add(1, Ordering::Relaxed);
        if tx.send(Job { cases, reply, qid }).is_err() {
            shard.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(Error::msg(format!("network {:?} is shutting down", self.name)));
        }
        Ok(())
    }

    fn shutdown(&self) {
        for shard in &self.shards {
            *shard.tx.lock().unwrap() = None;
        }
        for handle in self.workers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for ShardGroup {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A shard replica for `model`: the configured engine over the compiled
/// tree on the exact tier, a likelihood-weighting [`ApproxEngine`] (plus a
/// detached state — there is no arena to reset) on the approximate tier.
fn build_replica(model: &Compiled, engine_kind: EngineKind, cfg: &EngineConfig) -> (Box<dyn Engine>, TreeState) {
    match model {
        Compiled::Exact(jt) => (engine_kind.build(Arc::clone(jt), cfg), TreeState::fresh(jt)),
        Compiled::Approx { net, .. } => {
            (Box::new(ApproxEngine::from_net(Arc::clone(net), cfg)), TreeState::detached())
        }
    }
}

fn shard_worker(
    model: Compiled,
    engine_kind: EngineKind,
    cfg: EngineConfig,
    rx: mpsc::Receiver<Job>,
    depth: Arc<AtomicUsize>,
) {
    let (mut engine, mut state) = build_replica(&model, engine_kind, &cfg);
    while let Ok(job) = rx.recv() {
        let t0 = Instant::now();
        let Job { cases, reply, qid } = job;
        // a panicking case must not kill the shard: without the catch, the
        // worker dies with its depth stuck and ~1/N of the network's
        // queries fail as "shutting down" forever
        match reply {
            JobReply::Posteriors(reply) => {
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // trace root for the whole dispatch: engines run on this
                    // very thread, so their spans nest under it and the
                    // guard's drop publishes the query's span tree (ring /
                    // slow-query log)
                    let dispatch_span = crate::obs::trace::span("shard.infer");
                    dispatch_span.note(&format!("cases={}", cases.len()));
                    if let Some(q) = &qid {
                        crate::obs::trace::tag_qid(q);
                    }
                    engine.infer_batch(&mut state, &cases)
                }));
                depth.fetch_sub(1, Ordering::Relaxed);
                match outcome {
                    // the requester may have given up; a dead reply channel
                    // is fine
                    Ok(results) => {
                        let _ = reply.send((results, t0.elapsed()));
                    }
                    Err(_) => {
                        // engine pool and state may be mid-mutation: rebuild
                        let msg = "inference panicked; shard engine rebuilt";
                        let results = cases.iter().map(|_| Err(Error::msg(msg))).collect();
                        let _ = reply.send((results, t0.elapsed()));
                        (engine, state) = build_replica(&model, engine_kind, &cfg);
                    }
                }
            }
            JobReply::Mpe(reply) => {
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let dispatch_span = crate::obs::trace::span("shard.mpe");
                    dispatch_span.note(&format!("cases={}", cases.len()));
                    if let Some(q) = &qid {
                        crate::obs::trace::tag_qid(q);
                    }
                    engine.mpe_batch(&mut state, &cases)
                }));
                depth.fetch_sub(1, Ordering::Relaxed);
                match outcome {
                    Ok(results) => {
                        let _ = reply.send((results, t0.elapsed()));
                    }
                    Err(_) => {
                        let msg = "inference panicked; shard engine rebuilt";
                        let results = cases.iter().map(|_| Err(Error::msg(msg))).collect();
                        let _ = reply.send((results, t0.elapsed()));
                        (engine, state) = build_replica(&model, engine_kind, &cfg);
                    }
                }
            }
        }
    }
}

/// Routes queries to per-network shard groups.
pub struct Router {
    engine: EngineKind,
    engine_cfg: EngineConfig,
    shards_per_net: usize,
    groups: Mutex<HashMap<String, Arc<ShardGroup>>>,
}

impl Router {
    /// Create a router that gives every network `shards_per_net` shards of
    /// `engine` replicas.
    pub fn new(engine: EngineKind, engine_cfg: EngineConfig, shards_per_net: usize) -> Self {
        Router { engine, engine_cfg, shards_per_net, groups: Mutex::new(HashMap::new()) }
    }

    /// Ensure a shard group exists for `name`, spawning workers if needed.
    pub fn ensure(&self, name: &str, model: &Compiled) -> Result<()> {
        let mut groups = self.groups.lock().unwrap();
        if !groups.contains_key(name) {
            let group =
                Arc::new(ShardGroup::new(name, model.clone(), self.shards_per_net, self.engine, &self.engine_cfg)?);
            groups.insert(name.to_string(), group);
        }
        Ok(())
    }

    /// Tear a group down (workers join after draining queued jobs).
    pub fn remove(&self, name: &str) {
        let group = self.groups.lock().unwrap().remove(name);
        drop(group); // join outside the lock
    }

    /// The group serving `name`, if any.
    pub fn group(&self, name: &str) -> Option<Arc<ShardGroup>> {
        self.groups.lock().unwrap().get(name).cloned()
    }

    /// Dispatch a query to `name`'s group.
    pub fn query(&self, name: &str, ev: Evidence) -> Result<(Posteriors, Duration)> {
        self.query_tagged(name, ev, None)
    }

    /// [`Router::query`] with an optional query id for trace correlation.
    pub fn query_tagged(&self, name: &str, ev: Evidence, qid: Option<String>) -> Result<(Posteriors, Duration)> {
        let group = self.group(name).ok_or_else(|| Error::msg(format!("network {name:?} is not loaded")))?;
        group.dispatch_tagged(ev, qid)
    }

    /// Dispatch a multi-case batch to `name`'s group (one shard dispatch).
    pub fn query_batch(&self, name: &str, cases: Vec<Evidence>) -> Result<(Vec<Result<Posteriors>>, Duration)> {
        let group = self.group(name).ok_or_else(|| Error::msg(format!("network {name:?} is not loaded")))?;
        group.dispatch_batch(cases)
    }

    /// Dispatch an MPE query to `name`'s group.
    pub fn mpe(&self, name: &str, ev: Evidence) -> Result<(MpeResult, Duration)> {
        self.mpe_tagged(name, ev, None)
    }

    /// [`Router::mpe`] with an optional query id for trace correlation.
    pub fn mpe_tagged(&self, name: &str, ev: Evidence, qid: Option<String>) -> Result<(MpeResult, Duration)> {
        let group = self.group(name).ok_or_else(|| Error::msg(format!("network {name:?} is not loaded")))?;
        group.dispatch_mpe_tagged(ev, qid)
    }

    /// Dispatch a multi-case MPE batch to `name`'s group (one dispatch).
    pub fn mpe_batch(&self, name: &str, cases: Vec<Evidence>) -> Result<(Vec<Result<MpeResult>>, Duration)> {
        let group = self.group(name).ok_or_else(|| Error::msg(format!("network {name:?} is not loaded")))?;
        group.dispatch_mpe_batch(cases)
    }

    /// Names with live shard groups, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.groups.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::tree::JunctionTree;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn asia_tree() -> Arc<JunctionTree> {
        Arc::new(JunctionTree::compile(&embedded::asia(), TriangulationHeuristic::MinFill).unwrap())
    }

    fn asia_model() -> Compiled {
        Compiled::Exact(asia_tree())
    }

    #[test]
    fn dispatch_matches_direct_inference() {
        let jt = asia_tree();
        let group = ShardGroup::new(
            "asia",
            Compiled::Exact(Arc::clone(&jt)),
            2,
            EngineKind::Seq,
            &EngineConfig::default().with_threads(1),
        )
        .unwrap();
        let ev = Evidence::from_pairs(&jt.net, &[("smoke", "yes")]).unwrap();
        let (post, _service) = group.dispatch(ev.clone()).unwrap();

        let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let reference = engine.infer(&mut state, &ev).unwrap();
        assert!(post.max_abs_diff(&reference) < 1e-12);
    }

    #[test]
    fn errors_propagate_and_workers_survive() {
        let jt = asia_tree();
        let group = ShardGroup::new(
            "asia",
            Compiled::Exact(Arc::clone(&jt)),
            1,
            EngineKind::Seq,
            &EngineConfig::default().with_threads(1),
        )
        .unwrap();
        // impossible evidence: either=no contradicts lung=yes
        let bad = Evidence::from_pairs(&jt.net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(group.dispatch(bad).is_err());
        // the same worker still serves good queries afterwards
        let ok = Evidence::from_pairs(&jt.net, &[("smoke", "no")]).unwrap();
        let (post, _) = group.dispatch(ok).unwrap();
        let lung = post.marginal(&jt.net, "lung").unwrap();
        assert!((lung[0] - 0.01).abs() < 1e-9);
        assert_eq!(group.depths(), vec![0]);
    }

    #[test]
    fn batch_dispatch_is_one_job_with_per_case_results() {
        let jt = asia_tree();
        let group = ShardGroup::new(
            "asia",
            Compiled::Exact(Arc::clone(&jt)),
            2,
            EngineKind::Batched,
            &EngineConfig::default().with_threads(1).with_batch(3),
        )
        .unwrap();
        let good = Evidence::from_pairs(&jt.net, &[("smoke", "yes")]).unwrap();
        let bad = Evidence::from_pairs(&jt.net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let (results, _service) =
            group.dispatch_batch(vec![good.clone(), bad, Evidence::none(), good.clone()]).unwrap();
        assert_eq!(results.len(), 4);
        assert!(results[1].is_err());
        let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let none = Evidence::none();
        for (i, ev) in [(0usize, &good), (2, &none), (3, &good)] {
            let reference = engine.infer(&mut state, ev).unwrap();
            assert!(results[i].as_ref().unwrap().max_abs_diff(&reference) < 1e-9, "case {i}");
        }
        // empty batch short-circuits without touching a shard
        let (empty, service) = group.dispatch_batch(Vec::new()).unwrap();
        assert!(empty.is_empty());
        assert_eq!(service, Duration::ZERO);
        assert_eq!(group.depths(), vec![0, 0]);
    }

    #[test]
    fn mpe_dispatch_matches_direct_mpe_and_isolates_failures() {
        let jt = asia_tree();
        let group = ShardGroup::new(
            "asia",
            Compiled::Exact(Arc::clone(&jt)),
            2,
            EngineKind::Batched,
            &EngineConfig::default().with_threads(1).with_batch(3),
        )
        .unwrap();
        let good = Evidence::from_pairs(&jt.net, &[("xray", "yes")]).unwrap();
        let bad = Evidence::from_pairs(&jt.net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let (results, _service) =
            group.dispatch_mpe_batch(vec![good.clone(), bad, Evidence::none()]).unwrap();
        assert_eq!(results.len(), 3);
        assert!(results[1].is_err());
        let sched = crate::jt::schedule::Schedule::build(&jt, crate::jt::schedule::RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        for (i, ev) in [(0usize, &good), (2, &Evidence::none())] {
            let want = crate::jt::mpe::most_probable_explanation(&jt, &sched, &mut state, ev).unwrap();
            let got = results[i].as_ref().unwrap();
            assert_eq!(got.assignment, want.assignment, "case {i}");
            assert_eq!(got.log_prob.to_bits(), want.log_prob.to_bits(), "case {i}");
        }
        // single-case entry point and clean depths afterwards
        let (one, _) = group.dispatch_mpe(good.clone()).unwrap();
        assert_eq!(one.assignment, results[0].as_ref().unwrap().assignment);
        assert_eq!(group.depths(), vec![0, 0]);
        // the approximate tier refuses MPE instead of approximating it
        let net = Arc::new(embedded::asia());
        let approx = ShardGroup::new(
            "asia-lw",
            Compiled::Approx { net, cost: 1e12 },
            1,
            EngineKind::Hybrid,
            &EngineConfig::default().with_threads(1),
        )
        .unwrap();
        assert!(approx.dispatch_mpe(good).is_err());
    }

    #[test]
    fn router_spreads_queries_across_shards() {
        let model = asia_model();
        let net = model.net().clone();
        let router = Router::new(EngineKind::Seq, EngineConfig::default().with_threads(1), 3);
        router.ensure("asia", &model).unwrap();
        router.ensure("asia", &model).unwrap(); // idempotent
        assert_eq!(router.names(), vec!["asia".to_string()]);
        assert_eq!(router.group("asia").unwrap().n_shards(), 3);
        for _ in 0..6 {
            let (post, _) = router.query("asia", Evidence::none()).unwrap();
            let lung = post.marginal(&net, "lung").unwrap();
            assert!((lung[0] - 0.055).abs() < 1e-9);
        }
        assert!(router.query("unloaded", Evidence::none()).is_err());
        router.remove("asia");
        assert!(router.query("asia", Evidence::none()).is_err());
    }

    #[test]
    fn approx_model_shards_serve_estimates() {
        // an approximate-tier model runs LW replicas behind the same
        // dispatch surface; answers are deterministic across shards
        // because every replica shares the seed and chunk layout
        let net = Arc::new(embedded::asia());
        let model = Compiled::Approx { net: Arc::clone(&net), cost: 1e12 };
        let group = ShardGroup::new(
            "asia",
            model,
            2,
            EngineKind::Hybrid, // ignored on the approximate tier
            &EngineConfig::default().with_threads(1).with_samples(20_000),
        )
        .unwrap();
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let (a, _) = group.dispatch(ev.clone()).unwrap();
        let (b, _) = group.dispatch(ev).unwrap();
        let info = a.approx.as_ref().expect("approximate posteriors carry their contract");
        assert!(info.n_samples >= 20_000);
        let lung = a.marginal(&net, "lung").unwrap()[0];
        assert!((lung - 0.1).abs() < 3.0 * info.half_width(0.1).max(1e-3), "{lung}");
        // same seed, same chunks: shard identity cannot change the answer
        assert_eq!(a.probs, b.probs);
    }
}
