//! The multi-network serving fleet — many trees, many evidence streams,
//! one process.
//!
//! The [`crate::coordinator`] serves one compiled tree per process; this
//! module scales that to a *fleet*: a [`registry::Registry`] compiles and
//! LRU-caches junction trees by name, a [`router::Router`] owns per-network
//! shard groups of engine replicas and dispatches queries round-robin with
//! per-shard depth accounting, [`metrics::FleetMetrics`] aggregates
//! per-network qps and latency percentiles, and [`session::Session`] +
//! [`server::FleetServer`] extend the line protocol with multi-network and
//! streaming-evidence verbs:
//!
//! ```text
//! LOAD <net>              compile/cache a network (idempotent)
//! LEARN <name> <spec> <n> <seed>
//!                         sample n rows from <spec>, learn structure +
//!                         parameters (crate::learn), register as <name>
//!                         — the learned net is immediately servable.
//!                         Deterministic: any backend re-running the verb
//!                         produces the bit-identical network. Repeating
//!                         the exact spec is an idempotent cache hit; the
//!                         same name with different provenance is refused
//!                         (EVICT it first).
//! USE <net>               select the session's network (must be loaded)
//! NETS                    list resident networks with size/compile stats
//! OBSERVE var=state ...   stage evidence deltas
//! RETRACT var ...         stage evidence removals
//! COMMIT                  apply staged deltas to the session's evidence
//! QUERY <var> [| ev ...]  posterior under committed (+ inline) evidence
//! MPE [| ev ...]          jointly most probable assignment under
//!                         committed (+ inline) evidence — max-product
//!                         over the same tree (exact tier only)
//! BATCH <n> <var>         open an n-case batch for <var>'s posterior
//! BATCH <n> MPE           open an n-case MPE batch (the literal verb
//!                         `MPE` as target; a variable named "MPE" is
//!                         shadowed — query it per-case via QUERY)
//! CASE [ev=state ...]     one batch case (committed evidence + inline,
//!                         inline wins); the n-th CASE dispatches all n
//!                         cases in ONE shard dispatch (one fused sweep
//!                         with the batched engine — lane-parallel max
//!                         sweeps for an MPE batch) and returns n reply
//!                         lines — n evidence lines in, n result
//!                         lines out. Any other verb aborts the batch.
//! STATS                   fleet-wide per-network counters and latency
//! METRICS                 Prometheus-style text exposition (header line
//!                         `OK metrics lines=<n>` followed by n lines):
//!                         per-net query counters and latency histograms,
//!                         registry LRU and connection gauges, plus the
//!                         process-global engine/compiler series
//! TRACE <on|off|last|qid> toggle per-query span recording / return the
//!                         most recent span tree as one line / look a
//!                         specific query up by its cluster-minted id
//!                         (`q<digits>`, propagated as a trailing `#qid`
//!                         token on QUERY/MPE lines)
//! PROFILE [on|off]        arm/disarm the pool parallelism profiler;
//!                         bare PROFILE returns the per-region report as
//!                         a counted block (`OK profile lines=<n>`):
//!                         per-worker busy/idle lanes, utilization,
//!                         load-imbalance ratio, barrier-wait share
//! PING                    liveness probe (the cluster tier's health check)
//! EVICT <net>             drop a network (cluster registry hand-off)
//! QUIT                    end the session
//! ```
//!
//! Sessions stream evidence *deltas* instead of resending full evidence
//! per query — the shape an evidence-stream workload (e.g. a sensor feed)
//! actually has. `BATCH` is the complementary throughput shape: a scoring
//! client (label a file of cases against one target) ships N cases and
//! gets N posteriors with one round of propagation amortization.

pub mod metrics;
pub mod registry;
pub mod router;
pub mod server;
pub mod session;

use std::sync::Arc;
use std::time::Duration;

use crate::engine::{EngineConfig, EngineKind};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::mpe::MpeResult;
use crate::jt::tree::JunctionTree;
use crate::Result;

pub use metrics::{FleetMetrics, NetSnapshot};
pub use registry::{Compiled, Registry, RegistryEntry, Tier};
pub use router::{Router, ShardGroup};
pub use server::FleetServer;
pub use session::{Session, SessionReply};

/// Fleet construction parameters.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Engine replicated in every shard.
    pub engine: EngineKind,
    /// Per-replica engine parameters (threads = intra-case parallelism).
    pub engine_cfg: EngineConfig,
    /// Shards (engine replicas) per network.
    pub shards: usize,
    /// Maximum resident compiled trees before LRU eviction.
    pub registry_capacity: usize,
    /// Tier threshold: loads whose *estimated* junction-tree cost (total
    /// clique state space) exceeds this fall back to the approximate
    /// likelihood-weighting tier instead of compiling. `INFINITY` (the
    /// default) keeps every load exact and skips estimation; `<= 0`
    /// forces every load approximate. Selecting
    /// [`EngineKind::Approx`] as the fleet engine has the same effect as
    /// `0.0` — an approximate fleet never compiles a tree.
    pub max_exact_cost: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            engine: EngineKind::Hybrid,
            engine_cfg: EngineConfig::default(),
            shards: 2,
            registry_capacity: 8,
            max_exact_cost: f64::INFINITY,
        }
    }
}

/// A multi-network serving fleet: registry + router + metrics.
pub struct Fleet {
    cfg: FleetConfig,
    registry: Registry,
    router: Router,
    metrics: FleetMetrics,
    /// Per-fleet observability registry (per-net counters/histograms plus
    /// LRU and connection gauges) — fleet-scoped, not process-global, so
    /// in-process fleets (tests, the cluster harness) stay isolated.
    obs: Arc<crate::obs::Registry>,
    /// Serializes load/evict/ensure so concurrent `LOAD`s cannot leave the
    /// registry and router disagreeing about which networks are servable.
    load_lock: std::sync::Mutex<()>,
}

impl Fleet {
    /// Create an empty fleet.
    pub fn new(cfg: FleetConfig) -> Self {
        let router = Router::new(cfg.engine, cfg.engine_cfg.clone(), cfg.shards);
        // an approximate fleet never compiles: EngineKind::Approx pins the
        // threshold to 0 so every load lands on the sampling tier
        let max_exact_cost = if cfg.engine == EngineKind::Approx { 0.0 } else { cfg.max_exact_cost };
        let registry = Registry::with_max_exact_cost(cfg.registry_capacity, max_exact_cost);
        let obs = Arc::new(crate::obs::Registry::default());
        // registry LRU accounting as live gauges (satellite of the verb
        // surface: previously counted nowhere, now scrapeable)
        let (hits, misses, evictions) = registry.lru_counter_handles();
        obs.register_gauge("fastbn_registry_lru_hits_total", move || {
            hits.load(std::sync::atomic::Ordering::Relaxed)
        });
        obs.register_gauge("fastbn_registry_lru_misses_total", move || {
            misses.load(std::sync::atomic::Ordering::Relaxed)
        });
        obs.register_gauge("fastbn_registry_lru_evictions_total", move || {
            evictions.load(std::sync::atomic::Ordering::Relaxed)
        });
        Fleet { registry, router, metrics: FleetMetrics::new(), obs, load_lock: std::sync::Mutex::new(()), cfg }
    }

    /// The configuration in use.
    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Load `spec` (idempotent) and make it servable: compile into the
    /// registry, spin its shard group up, and tear down any shard groups
    /// whose trees the load evicted. Returns the entry's accounting.
    ///
    /// A `learn:` spec that actually needs its pipeline run is resolved
    /// **before** the load lock is taken: learning can take minutes, and
    /// holding the lock across it would wedge every concurrent `LOAD` on
    /// this process behind one `LEARN` (timing their front-tier RPCs
    /// out). The registry re-runs its cache fast paths and provenance
    /// guard under the lock, so a racing duplicate converges on one tree
    /// and a racing different-provenance load still gets refused.
    pub fn load(&self, spec: &str) -> Result<RegistryEntry> {
        let is_learn = crate::learn::is_learn_spec(spec);
        let mut prelearned = None;
        let (serialized, loaded) = loop {
            if is_learn && prelearned.is_none() && self.learn_spec_needs_pipeline(spec)? {
                prelearned = Some(crate::bn::resolve_spec(spec)?);
            }
            let serialized = self.load_lock.lock().unwrap();
            if is_learn && prelearned.is_none() && self.learn_spec_needs_pipeline(spec)? {
                // the cache hit / refusal that justified skipping the
                // pipeline evaporated while we raced to the lock (a
                // concurrent EVICT): release and learn unlocked
                drop(serialized);
                continue;
            }
            let loaded = match prelearned.take() {
                Some(net) => self.registry.install(spec, net)?,
                None => self.registry.load(spec)?,
            };
            break (serialized, loaded);
        };
        let _serialized = serialized;
        for evicted in &loaded.evicted {
            self.router.remove(evicted);
            self.metrics.remove(evicted);
            self.obs.remove_matching(&format!("net=\"{evicted}\""));
        }
        self.router.ensure(&loaded.entry.name, &loaded.model)?;
        self.metrics.ensure(&loaded.entry.name, loaded.entry.tier);
        Ok(loaded.entry)
    }

    /// Would loading this `learn:` spec actually run the learning
    /// pipeline? False when the exact spec is an alias/cache hit or the
    /// name is resident from other provenance (registry refuses without
    /// resolving).
    fn learn_spec_needs_pipeline(&self, spec: &str) -> Result<bool> {
        let name = crate::learn::LearnSpec::parse(spec)?.name;
        Ok(self.registry.resident_name_for(spec).is_none() && self.registry.get(&name).is_none())
    }

    /// The compiled tree for a loaded network (refreshes its LRU stamp).
    /// `None` for approximate-tier residents — callers that can serve
    /// either tier want [`Fleet::model`].
    pub fn tree(&self, name: &str) -> Option<Arc<JunctionTree>> {
        self.registry.get(name).and_then(|m| m.jt().cloned())
    }

    /// The servable model for a loaded network — either tier (refreshes
    /// its LRU stamp).
    pub fn model(&self, name: &str) -> Option<Compiled> {
        self.registry.get(name)
    }

    /// Drop a network: registry entry, shard group, and metrics, under
    /// the same serialization as [`Fleet::load`]. Returns whether it was
    /// resident. This is the cluster hand-off path (`EVICT <net>`): when
    /// ownership moves to another backend process, the old owner frees
    /// the tree; sessions still pinned to it get the usual clean
    /// "evicted" error on their next verb.
    pub fn evict(&self, name: &str) -> bool {
        let _serialized = self.load_lock.lock().unwrap();
        let existed = self.registry.remove(name);
        if existed {
            self.router.remove(name);
            self.metrics.remove(name);
            self.obs.remove_matching(&format!("net=\"{name}\""));
        }
        existed
    }

    /// Run one query against a loaded network, recording metrics.
    pub fn query(&self, name: &str, ev: Evidence) -> Result<Posteriors> {
        self.query_tagged(name, ev, None)
    }

    /// [`Fleet::query`] with an optional cluster-minted query id: the
    /// shard worker tags its trace root with it so `TRACE <qid>` can find
    /// this dispatch's span tree later. Accounting is identical.
    pub fn query_tagged(&self, name: &str, ev: Evidence, qid: Option<String>) -> Result<Posteriors> {
        // serving traffic refreshes the LRU stamp: a hot network must not
        // be evicted in favor of an idle one just because it loaded first
        let _ = self.registry.get(name);
        match self.router.query_tagged(name, ev, qid) {
            Ok((post, service)) => {
                self.metrics.record(name, service, true);
                self.record_obs(name, service, &post);
                Ok(post)
            }
            Err(e) => {
                // a no-op for unknown names: record never mints entries
                self.metrics.record(name, Duration::ZERO, false);
                self.obs.counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)])).inc();
                Err(e)
            }
        }
    }

    /// Fold one successful query into the per-net observability series:
    /// count, latency histogram, and (for approx posteriors) the sampling
    /// health counters.
    fn record_obs(&self, name: &str, service: Duration, post: &Posteriors) {
        self.obs.counter(&crate::obs::series("fastbn_queries_total", &[("net", name)])).inc();
        self.obs.histogram(&crate::obs::series("fastbn_query_latency_us", &[("net", name)])).record(service);
        if let Some(info) = &post.approx {
            if self.metrics.record_approx(name, info) {
                self.obs.counter(&crate::obs::series("fastbn_approx_degenerate_total", &[("net", name)])).inc();
            }
        }
    }

    /// Run a multi-case batch against a loaded network in **one shard
    /// dispatch** (the `BATCH` verb path). Per-case outcomes come back in
    /// order; metrics record each case with its share of the shard-side
    /// service time. The outer `Err` is transport-level only (network not
    /// loaded, shard worker gone).
    pub fn query_batch(&self, name: &str, cases: Vec<Evidence>) -> Result<Vec<Result<Posteriors>>> {
        if cases.is_empty() {
            return Ok(Vec::new());
        }
        let n = cases.len() as u32;
        let _ = self.registry.get(name); // refresh the LRU stamp, as in query()
        match self.router.query_batch(name, cases) {
            Ok((results, service)) => {
                let per_case = service / n;
                for r in &results {
                    self.metrics.record(name, per_case, r.is_ok());
                    match r {
                        Ok(post) => self.record_obs(name, per_case, post),
                        Err(_) => self
                            .obs
                            .counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)]))
                            .inc(),
                    }
                }
                Ok(results)
            }
            Err(e) => {
                // a transport-level failure failed every case in the batch;
                // record them all so STATS error counts match what the
                // client saw (n ERR lines)
                for _ in 0..n {
                    self.metrics.record(name, Duration::ZERO, false);
                }
                self.obs
                    .counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)]))
                    .add(n as u64);
                Err(e)
            }
        }
    }

    /// Run one MPE query against a loaded network, recording metrics
    /// (same counters and latency series as [`Fleet::query`] — an MPE is
    /// a query to the serving stack).
    pub fn mpe(&self, name: &str, ev: Evidence) -> Result<MpeResult> {
        self.mpe_tagged(name, ev, None)
    }

    /// [`Fleet::mpe`] with an optional query id for trace correlation
    /// (see [`Fleet::query_tagged`]).
    pub fn mpe_tagged(&self, name: &str, ev: Evidence, qid: Option<String>) -> Result<MpeResult> {
        let _ = self.registry.get(name); // refresh the LRU stamp, as in query()
        match self.router.mpe_tagged(name, ev, qid) {
            Ok((result, service)) => {
                self.metrics.record(name, service, true);
                self.obs.counter(&crate::obs::series("fastbn_queries_total", &[("net", name)])).inc();
                self.obs
                    .histogram(&crate::obs::series("fastbn_query_latency_us", &[("net", name)]))
                    .record(service);
                Ok(result)
            }
            Err(e) => {
                self.metrics.record(name, Duration::ZERO, false);
                self.obs.counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)])).inc();
                Err(e)
            }
        }
    }

    /// Run a multi-case MPE batch against a loaded network in **one shard
    /// dispatch** (`BATCH <n> MPE`). Accounting mirrors
    /// [`Fleet::query_batch`]: per-case records at their share of the
    /// shard-side service time, outer `Err` reserved for transport.
    pub fn mpe_batch(&self, name: &str, cases: Vec<Evidence>) -> Result<Vec<Result<MpeResult>>> {
        if cases.is_empty() {
            return Ok(Vec::new());
        }
        let n = cases.len() as u32;
        let _ = self.registry.get(name);
        match self.router.mpe_batch(name, cases) {
            Ok((results, service)) => {
                let per_case = service / n;
                for r in &results {
                    self.metrics.record(name, per_case, r.is_ok());
                    match r {
                        Ok(_) => {
                            self.obs
                                .counter(&crate::obs::series("fastbn_queries_total", &[("net", name)]))
                                .inc();
                            self.obs
                                .histogram(&crate::obs::series("fastbn_query_latency_us", &[("net", name)]))
                                .record(per_case);
                        }
                        Err(_) => self
                            .obs
                            .counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)]))
                            .inc(),
                    }
                }
                Ok(results)
            }
            Err(e) => {
                for _ in 0..n {
                    self.metrics.record(name, Duration::ZERO, false);
                }
                self.obs
                    .counter(&crate::obs::series("fastbn_query_errors_total", &[("net", name)]))
                    .add(n as u64);
                Err(e)
            }
        }
    }

    /// Registry accounting for every resident network, sorted by name.
    pub fn loaded(&self) -> Vec<RegistryEntry> {
        self.registry.entries()
    }

    /// The metrics aggregator.
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// The shard router (shard counts and depths, for diagnostics).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The single-line `STATS` reply.
    pub fn stats_line(&self) -> String {
        self.metrics.render()
    }

    /// The fleet-scoped observability registry (per-net query series,
    /// LRU/connection gauges). Engine- and compiler-level series live in
    /// [`crate::obs::global`]; the two use disjoint series names.
    pub fn obs(&self) -> &Arc<crate::obs::Registry> {
        &self.obs
    }

    /// The `METRICS` verb body: fleet-scoped series followed by the
    /// process-global engine/compiler series, Prometheus text format.
    /// Empty registries contribute nothing (the body may be empty).
    pub fn metrics_exposition(&self) -> String {
        let parts = [self.obs.render(), crate::obs::global().render()];
        parts.iter().filter(|p| !p.is_empty()).cloned().collect::<Vec<_>>().join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_fleet() -> Fleet {
        Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 2,
            registry_capacity: 4,
            max_exact_cost: f64::INFINITY,
        })
    }

    #[test]
    fn load_query_and_stats_roundtrip() {
        let fleet = small_fleet();
        assert_eq!(fleet.load("asia").unwrap().name, "asia");
        assert_eq!(fleet.load("asia").unwrap().name, "asia"); // idempotent
        let jt = fleet.tree("asia").unwrap();
        let ev = Evidence::from_pairs(&jt.net, &[("smoke", "yes")]).unwrap();
        let post = fleet.query("asia", ev).unwrap();
        assert!((post.marginal(&jt.net, "lung").unwrap()[0] - 0.1).abs() < 1e-9);
        let stats = fleet.stats_line();
        assert!(stats.contains("| asia queries=1"), "{stats}");
    }

    #[test]
    fn eviction_tears_the_shard_group_down() {
        let fleet = Fleet::new(FleetConfig { registry_capacity: 1, shards: 1, ..small_fleet().cfg });
        fleet.load("asia").unwrap();
        fleet.load("cancer").unwrap();
        assert_eq!(fleet.router().names(), vec!["cancer".to_string()]);
        assert!(fleet.query("asia", Evidence::none()).is_err());
        assert!(fleet.query("cancer", Evidence::none()).is_ok());
    }

    #[test]
    fn unknown_network_query_errors() {
        let fleet = small_fleet();
        assert!(fleet.query("asia", Evidence::none()).is_err());
    }

    #[test]
    fn mpe_roundtrip_records_metrics_and_matches_direct_mpe() {
        let fleet = small_fleet();
        fleet.load("asia").unwrap();
        let jt = fleet.tree("asia").unwrap();
        let ev = Evidence::from_pairs(&jt.net, &[("xray", "yes")]).unwrap();
        let got = fleet.mpe("asia", ev.clone()).unwrap();
        let sched = crate::jt::schedule::Schedule::build(&jt, crate::jt::schedule::RootStrategy::Center);
        let mut state = crate::jt::state::TreeState::fresh(&jt);
        let want = crate::jt::mpe::most_probable_explanation(&jt, &sched, &mut state, &ev).unwrap();
        assert_eq!(got.assignment, want.assignment);
        assert_eq!(got.log_prob.to_bits(), want.log_prob.to_bits());
        // batch path: per-case slots, failures isolated, metrics recorded
        let bad = Evidence::from_pairs(&jt.net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let results = fleet.mpe_batch("asia", vec![ev.clone(), bad, ev]).unwrap();
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok() && results[2].is_ok());
        assert!(results[1].is_err());
        assert_eq!(results[0].as_ref().unwrap().assignment, want.assignment);
        let body = fleet.metrics_exposition();
        assert!(body.contains("fastbn_queries_total{net=\"asia\"} 3"), "{body}");
        assert!(body.contains("fastbn_query_errors_total{net=\"asia\"} 1"), "{body}");
        assert!(fleet.mpe("ghost", Evidence::none()).is_err());
    }

    #[test]
    fn cost_threshold_falls_back_to_the_approximate_tier() {
        let fleet = Fleet::new(FleetConfig {
            engine_cfg: EngineConfig::default().with_threads(1).with_samples(20_000),
            shards: 1,
            max_exact_cost: 1e6,
            ..small_fleet().cfg
        });
        // tractable: stays exact
        let asia = fleet.load("asia").unwrap();
        assert_eq!(asia.tier, Tier::Exact);
        assert!(fleet.tree("asia").is_some());
        // intractable: served anyway, on the sampling tier
        let entry = fleet.load("intractable-sim").unwrap();
        assert_eq!(entry.tier, Tier::Approx);
        assert!(entry.cost.unwrap() > 1e6);
        assert!(fleet.tree("intractable-sim").is_none(), "no tree on the approximate tier");
        let model = fleet.model("intractable-sim").unwrap();
        assert!(model.is_approx());
        let net = model.net();
        let ev = Evidence::from_pairs(net, &[(net.vars[0].name.as_str(), net.vars[0].states[0].as_str())]).unwrap();
        let post = fleet.query("intractable-sim", ev).unwrap();
        let info = post.approx.expect("approximate posteriors carry their contract");
        assert!(info.effective_samples > 0.0);
        assert!(post.probs.iter().all(|p| (p.iter().sum::<f64>() - 1.0).abs() < 1e-9));
        // the exact resident still answers exactly
        assert!(fleet.query("asia", Evidence::none()).unwrap().approx.is_none());
    }

    #[test]
    fn obs_series_track_queries_and_die_with_eviction() {
        let fleet = small_fleet();
        fleet.load("asia").unwrap();
        fleet.query("asia", Evidence::none()).unwrap();
        fleet.query("asia", Evidence::none()).unwrap();
        assert!(fleet.query("asia", Evidence::from_pairs(&fleet.tree("asia").unwrap().net, &[]).unwrap()).is_ok());
        let body = fleet.metrics_exposition();
        assert!(body.contains("fastbn_queries_total{net=\"asia\"} 3"), "{body}");
        assert!(body.contains("fastbn_query_latency_us_count{net=\"asia\"} 3"), "{body}");
        assert!(body.contains("fastbn_registry_lru_misses_total 1"), "{body}");
        // a failed query counts errors, not queries
        assert!(fleet.query("ghost", Evidence::none()).is_err());
        let body = fleet.metrics_exposition();
        assert!(body.contains("fastbn_query_errors_total{net=\"ghost\"} 1"), "{body}");
        // eviction reaps the per-net series (counters and histogram alike)
        fleet.evict("asia");
        let body = fleet.metrics_exposition();
        assert!(!body.contains("net=\"asia\""), "{body}");
    }

    #[test]
    fn evict_frees_registry_router_and_metrics_together() {
        let fleet = small_fleet();
        fleet.load("asia").unwrap();
        fleet.query("asia", Evidence::none()).unwrap();
        assert!(fleet.evict("asia"));
        assert!(fleet.tree("asia").is_none());
        assert!(fleet.router().names().is_empty());
        assert!(fleet.stats_line().contains("nets=0"), "{}", fleet.stats_line());
        assert!(fleet.query("asia", Evidence::none()).is_err());
        assert!(!fleet.evict("asia")); // idempotent
        // an evicted network loads back cleanly
        fleet.load("asia").unwrap();
        assert!(fleet.query("asia", Evidence::none()).is_ok());
    }
}
