//! Line-protocol TCP front end for a [`Fleet`] (`fastbn serve --nets …`).
//!
//! Connection threads are thin: they parse lines into a
//! [`crate::fleet::session::Session`] and write replies; all inference
//! runs on the router's shard workers, so a thousand idle connections cost
//! a thousand parked threads, not a thousand engines. Finished connection
//! threads are reaped (joined) in the accept loop — the handle list stays
//! proportional to *live* connections, not connections ever accepted.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::server::{run_accept_loop, serve_lines};
use crate::fleet::session::{Session, SessionReply};
use crate::fleet::Fleet;
use crate::Result;

/// Server handle; dropping it stops accepting and joins every thread.
pub struct FleetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    reaped: Arc<AtomicU64>,
    fleet: Arc<Fleet>,
}

/// Decrements the live-connection gauge however the handler exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl FleetServer {
    /// Start serving `fleet` on `bind` (use port 0 for an ephemeral port).
    pub fn start(fleet: Arc<Fleet>, bind: &str) -> Result<FleetServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let reaped = Arc::new(AtomicU64::new(0));

        let accept_stop = Arc::clone(&stop);
        let accept_active = Arc::clone(&active);
        let accept_reaped = Arc::clone(&reaped);
        let accept_fleet = Arc::clone(&fleet);
        let accept_thread = std::thread::Builder::new().name("fleet-accept".into()).spawn(move || {
            run_accept_loop(&listener, &accept_stop, &accept_reaped, |stream| {
                let fleet = Arc::clone(&accept_fleet);
                let stop = Arc::clone(&accept_stop);
                accept_active.fetch_add(1, Ordering::Relaxed);
                let guard = ConnGuard(Arc::clone(&accept_active));
                std::thread::spawn(move || {
                    let _guard = guard;
                    let _ = handle_connection(stream, fleet, stop);
                })
            });
        })?;

        Ok(FleetServer { addr, stop, accept_thread: Some(accept_thread), active, reaped, fleet })
    }

    /// Bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The fleet being served.
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// Live connection count.
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Finished connection threads joined by the accept loop so far.
    pub fn reaped_connections(&self) -> u64 {
        self.reaped.load(Ordering::Relaxed)
    }

    /// Stop accepting and wait for every thread to end.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn handle_connection(stream: TcpStream, fleet: Arc<Fleet>, stop: Arc<AtomicBool>) -> Result<()> {
    let mut session = Session::new(fleet);
    serve_lines(stream, &stop, move |line| match session.handle(line) {
        SessionReply::Line(s) => Some(s),
        SessionReply::Quit => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};
    use crate::fleet::FleetConfig;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> FleetServer {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 2,
            registry_capacity: 4,
        }));
        FleetServer::start(fleet, "127.0.0.1:0").unwrap()
    }

    fn ask(addr: std::net::SocketAddr, requests: &[&str]) -> Vec<String> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        for r in requests {
            stream.write_all(r.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            out.push(line.trim().to_string());
        }
        out
    }

    #[test]
    fn serves_the_fleet_protocol() {
        let server = start();
        let replies = ask(
            server.addr(),
            &[
                "LOAD asia",
                "USE asia",
                "OBSERVE smoke=yes",
                "COMMIT",
                "QUERY lung",
                "NETS",
                "STATS",
                "BOGUS",
            ],
        );
        assert!(replies[0].starts_with("OK loaded asia"), "{}", replies[0]);
        assert!(replies[1].starts_with("OK using asia"), "{}", replies[1]);
        assert!(replies[2].starts_with("OK staged 1"), "{}", replies[2]);
        assert!(replies[3].starts_with("OK committed evidence=1"), "{}", replies[3]);
        assert!(replies[4].starts_with("OK yes=0.100000"), "{}", replies[4]);
        assert!(replies[5].starts_with("OK nets=1 asia["), "{}", replies[5]);
        assert!(replies[6].contains("| asia queries=1"), "{}", replies[6]);
        assert!(replies[7].starts_with("ERR unknown verb"), "{}", replies[7]);
        server.shutdown();
    }

    #[test]
    fn sessions_are_independent() {
        let server = start();
        // session 1 loads and commits evidence; session 2 sees the loaded
        // net (fleet state) but not the evidence (session state)
        let r1 = ask(server.addr(), &["LOAD asia", "USE asia", "OBSERVE smoke=yes", "COMMIT", "QUERY lung"]);
        assert!(r1[4].starts_with("OK yes=0.100000"), "{}", r1[4]);
        let r2 = ask(server.addr(), &["USE asia", "QUERY lung"]);
        assert!(r2[0].starts_with("OK using asia"), "{}", r2[0]);
        assert!(r2[1].starts_with("OK yes=0.055000"), "{}", r2[1]);
        server.shutdown();
    }

    #[test]
    fn finished_connections_are_reaped() {
        let server = start();
        for _ in 0..3 {
            let replies = ask(server.addr(), &["NETS", "QUIT"]);
            assert!(replies[0].starts_with("OK nets="), "{}", replies[0]);
        }
        // the accept loop ticks every ~5ms; give it time to join all three
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while server.reaped_connections() < 3 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(server.reaped_connections() >= 3, "reaped {}", server.reaped_connections());
        assert_eq!(server.active_connections(), 0);
        server.shutdown();
    }
}
