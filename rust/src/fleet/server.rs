//! Line-protocol TCP front end for a [`Fleet`] (`fastbn serve --nets …`).
//!
//! Connection threads are thin: they parse lines into a
//! [`crate::fleet::session::Session`] and write replies; all inference
//! runs on the router's shard workers, so a thousand idle connections cost
//! a thousand parked threads, not a thousand engines. The accept loop,
//! per-connection threads, reaping, and shutdown live in the shared
//! [`crate::coordinator::server::LineServer`] scaffolding (the cluster
//! front tier serves through the same one).

use std::sync::Arc;

use crate::coordinator::server::LineServer;
use crate::fleet::session::{Session, SessionReply};
use crate::fleet::Fleet;
use crate::Result;

/// Server handle; dropping it stops accepting and joins every thread.
pub struct FleetServer {
    inner: LineServer,
    fleet: Arc<Fleet>,
}

impl FleetServer {
    /// Start serving `fleet` on `bind` (use port 0 for an ephemeral port).
    pub fn start(fleet: Arc<Fleet>, bind: &str) -> Result<FleetServer> {
        let session_fleet = Arc::clone(&fleet);
        let inner = LineServer::start(bind, "fleet-accept", move || {
            let mut session = Session::new(Arc::clone(&session_fleet));
            Box::new(move |line: &str| match session.handle(line) {
                SessionReply::Line(reply) => Some(reply),
                SessionReply::Quit => None,
            })
        })?;
        // connection gauges into the fleet's metrics registry: scrapers
        // see transport health next to query counters
        let active = inner.active_handle();
        fleet.obs().register_gauge("fastbn_connections_active", move || {
            active.load(std::sync::atomic::Ordering::Relaxed) as u64
        });
        let reaped = inner.reaped_handle();
        fleet.obs().register_gauge("fastbn_connections_reaped_total", move || {
            reaped.load(std::sync::atomic::Ordering::Relaxed)
        });
        Ok(FleetServer { inner, fleet })
    }

    /// Bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// The fleet being served.
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// Live connection count.
    pub fn active_connections(&self) -> usize {
        self.inner.active_connections()
    }

    /// Finished connection threads joined by the accept loop so far.
    pub fn reaped_connections(&self) -> u64 {
        self.inner.reaped_connections()
    }

    /// Stop accepting and wait for every thread to end.
    pub fn shutdown(mut self) {
        self.inner.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};
    use crate::fleet::FleetConfig;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn start() -> FleetServer {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 2,
            registry_capacity: 4,
            max_exact_cost: f64::INFINITY,
        }));
        FleetServer::start(fleet, "127.0.0.1:0").unwrap()
    }

    fn ask(addr: std::net::SocketAddr, requests: &[&str]) -> Vec<String> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        for r in requests {
            stream.write_all(r.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            out.push(line.trim().to_string());
        }
        out
    }

    #[test]
    fn serves_the_fleet_protocol() {
        let server = start();
        let replies = ask(
            server.addr(),
            &[
                "LOAD asia",
                "USE asia",
                "OBSERVE smoke=yes",
                "COMMIT",
                "QUERY lung",
                "NETS",
                "STATS",
                "BOGUS",
            ],
        );
        assert!(replies[0].starts_with("OK loaded asia"), "{}", replies[0]);
        assert!(replies[1].starts_with("OK using asia"), "{}", replies[1]);
        assert!(replies[2].starts_with("OK staged 1"), "{}", replies[2]);
        assert!(replies[3].starts_with("OK committed evidence=1"), "{}", replies[3]);
        assert!(replies[4].starts_with("OK yes=0.100000"), "{}", replies[4]);
        assert!(replies[5].starts_with("OK nets=1 asia["), "{}", replies[5]);
        assert!(replies[6].contains("| asia queries=1"), "{}", replies[6]);
        assert!(replies[7].starts_with("ERR unknown verb"), "{}", replies[7]);
        server.shutdown();
    }

    #[test]
    fn sessions_are_independent() {
        let server = start();
        // session 1 loads and commits evidence; session 2 sees the loaded
        // net (fleet state) but not the evidence (session state)
        let r1 = ask(server.addr(), &["LOAD asia", "USE asia", "OBSERVE smoke=yes", "COMMIT", "QUERY lung"]);
        assert!(r1[4].starts_with("OK yes=0.100000"), "{}", r1[4]);
        let r2 = ask(server.addr(), &["USE asia", "QUERY lung"]);
        assert!(r2[0].starts_with("OK using asia"), "{}", r2[0]);
        assert!(r2[1].starts_with("OK yes=0.055000"), "{}", r2[1]);
        server.shutdown();
    }

    #[test]
    fn finished_connections_are_reaped() {
        let server = start();
        for _ in 0..3 {
            let replies = ask(server.addr(), &["NETS", "QUIT"]);
            assert!(replies[0].starts_with("OK nets="), "{}", replies[0]);
        }
        // the accept loop ticks every ~5ms; give it time to join all three
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while server.reaped_connections() < 3 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(server.reaped_connections() >= 3, "reaped {}", server.reaped_connections());
        assert_eq!(server.active_connections(), 0);
        server.shutdown();
    }
}
