//! Network registry: loads and compiles junction trees on demand.
//!
//! Every network a fleet serves is compiled exactly once and shared behind
//! an [`Arc`]; the registry keys trees by the network's own name, accepts
//! any spec [`crate::bn::resolve_spec`] understands (embedded, paper-suite
//! analog, `.bif` / `.net` path), and bounds resident trees with an LRU
//! policy so a long-lived fleet can cycle through more networks than fit
//! in memory at once. Compile time and table size are recorded per entry —
//! the accounting the `NETS` protocol verb and the fleet bench report.
//!
//! Loading is **compile-once**: re-`LOAD`ing a spec whose network name is
//! already resident returns the cached tree, even if a file behind a path
//! spec has changed on disk since. To pick up a changed model, load it
//! under a new network name or restart the fleet (eviction also drops the
//! stale tree, but relying on LRU pressure for correctness is a mistake).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::bn::resolve_spec;
use crate::jt::tree::JunctionTree;
use crate::jt::triangulate::TriangulationHeuristic;
use crate::Result;

/// Accounting snapshot for one resident network.
#[derive(Clone, Debug)]
pub struct RegistryEntry {
    /// Network name (the registry key).
    pub name: String,
    /// Number of cliques in the compiled tree.
    pub cliques: usize,
    /// Total table entries (cliques + separators) — the memory driver.
    pub entries: usize,
    /// Wall time `JunctionTree::compile` took.
    pub compile_time: Duration,
}

struct Resident {
    jt: Arc<JunctionTree>,
    compile_time: Duration,
    last_used: u64,
}

struct Inner {
    nets: BTreeMap<String, Resident>,
    /// spec text → resident network name, so re-`LOAD`ing a path spec hits
    /// the cache without re-reading (or re-parsing) the file.
    aliases: BTreeMap<String, String>,
    clock: u64,
}

/// LRU-bounded cache of compiled junction trees, keyed by network name.
pub struct Registry {
    capacity: usize,
    inner: Mutex<Inner>,
}

/// Result of a [`Registry::load`]: the entry's accounting, the shared
/// tree, and any networks evicted to stay within capacity (the caller —
/// the fleet — tears down their shard groups).
pub struct Loaded {
    /// Accounting for the loaded network (`entry.name` is the key the
    /// network registered under — its own `net.name`).
    pub entry: RegistryEntry,
    /// The compiled tree.
    pub jt: Arc<JunctionTree>,
    /// Names evicted by this load, oldest first.
    pub evicted: Vec<String>,
    /// False when the load was served from cache.
    pub freshly_compiled: bool,
}

impl Registry {
    /// Create a registry holding at most `capacity` compiled trees
    /// (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Self {
        let inner = Inner { nets: BTreeMap::new(), aliases: BTreeMap::new(), clock: 0 };
        Registry { capacity: capacity.max(1), inner: Mutex::new(inner) }
    }

    fn entry_for(name: &str, jt: &JunctionTree, compile_time: Duration) -> RegistryEntry {
        RegistryEntry {
            name: name.to_string(),
            cliques: jt.n_cliques(),
            entries: jt.total_clique_entries() + jt.total_sep_entries(),
            compile_time,
        }
    }

    fn cache_hit(name: &str, jt: Arc<JunctionTree>, compile_time: Duration) -> Loaded {
        let entry = Self::entry_for(name, &jt, compile_time);
        Loaded { entry, jt, evicted: Vec::new(), freshly_compiled: false }
    }

    /// Load `spec`, compiling its junction tree unless already resident.
    ///
    /// The registry key is the *network's* name, so `LOAD asia` and
    /// `LOAD path/to/asia.bif` coalesce onto one tree. Compilation happens
    /// outside the registry lock; a concurrent duplicate load keeps the
    /// first tree that registered.
    pub fn load(&self, spec: &str) -> Result<Loaded> {
        // Fast paths: the spec is a resident name, or a spec we have
        // already resolved (a path) aliased onto a resident name — either
        // way the file is not re-read.
        if let Some((jt, ct)) = self.lookup(spec) {
            return Ok(Self::cache_hit(spec, jt, ct));
        }
        if let Some(name) = self.inner.lock().unwrap().aliases.get(spec).cloned() {
            if let Some((jt, ct)) = self.lookup(&name) {
                return Ok(Self::cache_hit(&name, jt, ct));
            }
        }
        let net = resolve_spec(spec)?;
        let name = net.name.clone();
        if name != spec {
            self.inner.lock().unwrap().aliases.insert(spec.to_string(), name.clone());
        }
        if let Some((jt, ct)) = self.lookup(&name) {
            return Ok(Self::cache_hit(&name, jt, ct));
        }
        let t0 = Instant::now();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
        let compile_time = t0.elapsed();

        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.nets.get(&name) {
            // a concurrent load won the race; keep its tree
            let (jt, ct) = (Arc::clone(&r.jt), r.compile_time);
            return Ok(Self::cache_hit(&name, jt, ct));
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.nets.insert(name.clone(), Resident { jt: Arc::clone(&jt), compile_time, last_used: stamp });
        let mut evicted = Vec::new();
        while inner.nets.len() > self.capacity {
            let oldest = inner
                .nets
                .iter()
                .filter(|(k, _)| **k != name)
                .min_by_key(|(_, r)| r.last_used)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    inner.nets.remove(&k);
                    inner.aliases.retain(|_, target| *target != k);
                    evicted.push(k);
                }
                None => break,
            }
        }
        let entry = Self::entry_for(&name, &jt, compile_time);
        Ok(Loaded { entry, jt, evicted, freshly_compiled: true })
    }

    /// Resident tree + its compile time, refreshing the LRU stamp.
    fn lookup(&self, name: &str) -> Option<(Arc<JunctionTree>, Duration)> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        inner.nets.get_mut(name).map(|r| {
            r.last_used = stamp;
            (Arc::clone(&r.jt), r.compile_time)
        })
    }

    /// Look a resident tree up by name, refreshing its LRU stamp.
    pub fn get(&self, name: &str) -> Option<Arc<JunctionTree>> {
        self.lookup(name).map(|(jt, _)| jt)
    }

    /// Drop a resident network (and any path aliases onto it). Returns
    /// whether it was resident. The cluster tier's `EVICT` hand-off verb
    /// lands here: after ownership moves to another backend process, the
    /// old owner frees the tree instead of serving a stale copy.
    pub fn remove(&self, name: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.nets.remove(name).is_some();
        if existed {
            inner.aliases.retain(|_, target| *target != name);
        }
        existed
    }

    /// Names of resident networks, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().nets.keys().cloned().collect()
    }

    /// Accounting snapshot of every resident network, sorted by name.
    pub fn entries(&self) -> Vec<RegistryEntry> {
        let inner = self.inner.lock().unwrap();
        inner.nets.iter().map(|(name, r)| Self::entry_for(name, &r.jt, r.compile_time)).collect()
    }

    /// Number of resident networks.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().nets.len()
    }

    /// True when nothing is loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_compiles_once_and_caches() {
        let reg = Registry::new(4);
        let a = reg.load("asia").unwrap();
        assert_eq!(a.entry.name, "asia");
        assert!(a.freshly_compiled);
        assert!(a.entry.entries > 0);
        let b = reg.load("asia").unwrap();
        assert!(!b.freshly_compiled);
        // cache hits report the original compile accounting
        assert_eq!(b.entry.compile_time, a.entry.compile_time);
        assert!(Arc::ptr_eq(&a.jt, &b.jt));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn unknown_spec_errors() {
        let reg = Registry::new(4);
        assert!(reg.load("no-such-network").is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let reg = Registry::new(2);
        reg.load("asia").unwrap();
        reg.load("cancer").unwrap();
        // touch asia so cancer becomes the LRU entry
        assert!(reg.get("asia").is_some());
        let l = reg.load("sprinkler").unwrap();
        assert_eq!(l.evicted, vec!["cancer".to_string()]);
        assert_eq!(reg.names(), vec!["asia".to_string(), "sprinkler".to_string()]);
        // evicted networks can be reloaded (recompiled)
        assert!(reg.load("cancer").unwrap().freshly_compiled);
    }

    #[test]
    fn path_specs_alias_onto_the_network_name() {
        let path = std::env::temp_dir().join(format!("fastbn-registry-{}.bif", std::process::id()));
        std::fs::write(&path, crate::bn::bif::write(&crate::bn::embedded::asia())).unwrap();
        let reg = Registry::new(4);
        let spec = path.to_str().unwrap();
        let a = reg.load(spec).unwrap();
        assert_eq!(a.entry.name, "asia");
        assert!(a.freshly_compiled);
        // the second load by the same path is an alias hit — cached tree,
        // no re-read — and loading by the bare name hits the same entry
        let b = reg.load(spec).unwrap();
        assert!(!b.freshly_compiled);
        assert!(Arc::ptr_eq(&a.jt, &b.jt));
        assert!(!reg.load("asia").unwrap().freshly_compiled);
        assert_eq!(reg.len(), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn remove_drops_the_tree_and_its_aliases() {
        let path = std::env::temp_dir().join(format!("fastbn-registry-rm-{}.bif", std::process::id()));
        std::fs::write(&path, crate::bn::bif::write(&crate::bn::embedded::asia())).unwrap();
        let reg = Registry::new(4);
        let spec = path.to_str().unwrap();
        reg.load(spec).unwrap();
        assert!(reg.remove("asia"));
        assert!(reg.get("asia").is_none());
        assert!(!reg.remove("asia")); // idempotent: already gone
        // the alias died with the entry: reloading by path recompiles
        assert!(reg.load(spec).unwrap().freshly_compiled);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn entries_report_size_and_compile_time() {
        let reg = Registry::new(4);
        reg.load("asia").unwrap();
        let e = &reg.entries()[0];
        assert_eq!(e.name, "asia");
        assert_eq!(e.cliques, 6);
        assert!(e.entries > 0);
    }
}
