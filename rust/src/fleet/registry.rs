//! Network registry: loads and compiles junction trees on demand.
//!
//! Every network a fleet serves is compiled exactly once and shared behind
//! an [`Arc`]; the registry keys trees by the network's own name, accepts
//! any spec [`crate::bn::resolve_spec`] understands (embedded, paper-suite
//! analog, `.bif` / `.net` path), and bounds resident trees with an LRU
//! policy so a long-lived fleet can cycle through more networks than fit
//! in memory at once. Compile time and table size are recorded per entry —
//! the accounting the `NETS` protocol verb and the fleet bench report.
//!
//! **Tier pick.** When a finite `max_exact_cost` is configured, loading
//! first *estimates* the junction-tree cost (sum over maximal cliques of
//! the product of member cardinalities — see
//! [`crate::jt::tree::estimate_cost`]) without materializing any tables.
//! At or under the threshold the network compiles exactly as before; past
//! it the registry keeps the raw [`Network`] and the fleet serves it with
//! the approximate likelihood-weighting engine instead — so a fleet can
//! `LOAD` *any* network without an exponential-size compile taking the
//! process down. The default threshold is `f64::INFINITY`: estimation is
//! skipped entirely and every load compiles exactly (the pre-tier
//! behavior). A threshold `<= 0` forces every network onto the
//! approximate tier.
//!
//! Loading is **compile-once**: re-`LOAD`ing a spec whose network name is
//! already resident returns the cached tree, even if a file behind a path
//! spec has changed on disk since. To pick up a changed model, load it
//! under a new network name or restart the fleet (eviction also drops the
//! stale tree, but relying on LRU pressure for correctness is a mistake).
//! `learn:` specs are the one exception with teeth: their provenance is
//! part of the spec, so a learn spec hitting a resident name of different
//! provenance is **refused** rather than cache-hit (see
//! [`Registry::load`]). The converse — an ordinary file spec resolving to
//! a name held by a learned net — keeps plain compile-once semantics:
//! the cached (learned) tree is served, and, as with any two specs
//! sharing a name, whoever records specs per name (the cluster
//! directory) records the latest one. Name collisions across unrelated
//! specs are an operator error compile-once cannot detect.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::bn::network::Network;
use crate::bn::resolve_spec;
use crate::jt::tree::JunctionTree;
use crate::jt::triangulate::TriangulationHeuristic;
use crate::Result;

/// Which engine family answers queries for a resident network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Compiled junction tree; posteriors are exact.
    Exact,
    /// Parallel likelihood weighting over the raw network; posteriors are
    /// estimates carrying CI half-widths (see
    /// [`crate::infer::query::ApproxInfo`]).
    Approx,
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Tier::Exact => "exact",
            Tier::Approx => "approx",
        })
    }
}

/// A servable model: either a compiled junction tree (exact tier) or the
/// raw network plus its estimated compile cost (approximate tier).
#[derive(Clone)]
pub enum Compiled {
    /// Exact tier: the compiled tree.
    Exact(Arc<JunctionTree>),
    /// Approximate tier: compilation was refused because `cost` (the
    /// estimated total clique state space) exceeded the registry's
    /// `max_exact_cost`.
    Approx {
        /// The raw network, sampled directly by the approximate engine.
        net: Arc<Network>,
        /// Estimated exact-compile cost that triggered the fallback.
        cost: f64,
    },
}

impl Compiled {
    /// The underlying network (both tiers have one).
    pub fn net(&self) -> &Network {
        match self {
            Compiled::Exact(jt) => &jt.net,
            Compiled::Approx { net, .. } => net,
        }
    }

    /// The compiled tree — `None` on the approximate tier.
    pub fn jt(&self) -> Option<&Arc<JunctionTree>> {
        match self {
            Compiled::Exact(jt) => Some(jt),
            Compiled::Approx { .. } => None,
        }
    }

    /// Which tier this model serves on.
    pub fn tier(&self) -> Tier {
        match self {
            Compiled::Exact(_) => Tier::Exact,
            Compiled::Approx { .. } => Tier::Approx,
        }
    }

    /// True on the approximate tier.
    pub fn is_approx(&self) -> bool {
        matches!(self, Compiled::Approx { .. })
    }

    /// Estimated exact-compile cost — `Some` only on the approximate tier
    /// (the exact tier skips estimation unless a threshold forced it, and
    /// its real size is in the entry's `entries`).
    pub fn cost(&self) -> Option<f64> {
        match self {
            Compiled::Exact(_) => None,
            Compiled::Approx { cost, .. } => Some(*cost),
        }
    }

    /// Identity comparison (same shared tree / network allocation) — the
    /// pin-revalidation primitive sessions use in place of `Arc::ptr_eq`.
    pub fn same(&self, other: &Compiled) -> bool {
        match (self, other) {
            (Compiled::Exact(a), Compiled::Exact(b)) => Arc::ptr_eq(a, b),
            (Compiled::Approx { net: a, .. }, Compiled::Approx { net: b, .. }) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// Accounting snapshot for one resident network.
#[derive(Clone, Debug)]
pub struct RegistryEntry {
    /// Network name (the registry key).
    pub name: String,
    /// Number of cliques in the compiled tree (0 on the approximate tier).
    pub cliques: usize,
    /// Total table entries (cliques + separators) — the memory driver.
    /// 0 on the approximate tier: nothing is materialized.
    pub entries: usize,
    /// Wall time the load spent compiling (tier pick included).
    pub compile_time: Duration,
    /// Which engine family serves this network.
    pub tier: Tier,
    /// Estimated exact-compile cost — `Some` only on the approximate tier.
    pub cost: Option<f64>,
}

struct Resident {
    model: Compiled,
    compile_time: Duration,
    last_used: u64,
}

struct Inner {
    nets: BTreeMap<String, Resident>,
    /// spec text → resident network name, so re-`LOAD`ing a path spec hits
    /// the cache without re-reading (or re-parsing) the file.
    aliases: BTreeMap<String, String>,
    clock: u64,
}

/// LRU-bounded cache of compiled junction trees, keyed by network name.
pub struct Registry {
    capacity: usize,
    max_exact_cost: f64,
    inner: Mutex<Inner>,
    /// LRU accounting over `load`/`install` calls (not `get` lookups):
    /// loads served from cache. `Arc`'d so the fleet can hand live
    /// handles to metrics gauges without holding the registry.
    hits: Arc<AtomicU64>,
    /// Loads that actually resolved and compiled (`freshly_compiled`).
    misses: Arc<AtomicU64>,
    /// Networks evicted by capacity pressure (not explicit `remove`).
    evictions: Arc<AtomicU64>,
}

/// Result of a [`Registry::load`]: the entry's accounting, the shared
/// model, and any networks evicted to stay within capacity (the caller —
/// the fleet — tears down their shard groups).
pub struct Loaded {
    /// Accounting for the loaded network (`entry.name` is the key the
    /// network registered under — its own `net.name`).
    pub entry: RegistryEntry,
    /// The servable model (compiled tree or approximate-tier network).
    pub model: Compiled,
    /// Names evicted by this load, oldest first.
    pub evicted: Vec<String>,
    /// False when the load was served from cache.
    pub freshly_compiled: bool,
}

impl Registry {
    /// Create a registry holding at most `capacity` compiled trees
    /// (clamped to ≥ 1), always compiling exactly (no cost threshold).
    pub fn new(capacity: usize) -> Self {
        Self::with_max_exact_cost(capacity, f64::INFINITY)
    }

    /// [`Registry::new`] with a tier threshold: loads whose estimated
    /// exact-compile cost exceeds `max_exact_cost` are kept as raw
    /// networks for the approximate tier. `INFINITY` skips estimation
    /// entirely; a threshold `<= 0` forces every load approximate.
    pub fn with_max_exact_cost(capacity: usize, max_exact_cost: f64) -> Self {
        let inner = Inner { nets: BTreeMap::new(), aliases: BTreeMap::new(), clock: 0 };
        Registry {
            capacity: capacity.max(1),
            max_exact_cost,
            inner: Mutex::new(inner),
            hits: Arc::new(AtomicU64::new(0)),
            misses: Arc::new(AtomicU64::new(0)),
            evictions: Arc::new(AtomicU64::new(0)),
        }
    }

    /// LRU accounting: `(hits, misses, evictions)` over loads (see the
    /// field docs). Surfaced as gauges on the fleet's metrics registry.
    pub fn lru_counters(&self) -> (u64, u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed), self.evictions.load(Ordering::Relaxed))
    }

    /// Live handles to the LRU counters, for gauge callbacks that must
    /// outlive any borrow of the registry.
    pub fn lru_counter_handles(&self) -> (Arc<AtomicU64>, Arc<AtomicU64>, Arc<AtomicU64>) {
        (Arc::clone(&self.hits), Arc::clone(&self.misses), Arc::clone(&self.evictions))
    }

    fn entry_for(name: &str, model: &Compiled, compile_time: Duration) -> RegistryEntry {
        let (cliques, entries) = match model.jt() {
            Some(jt) => (jt.n_cliques(), jt.total_clique_entries() + jt.total_sep_entries()),
            None => (0, 0),
        };
        RegistryEntry {
            name: name.to_string(),
            cliques,
            entries,
            compile_time,
            tier: model.tier(),
            cost: model.cost(),
        }
    }

    fn cache_hit(name: &str, model: Compiled, compile_time: Duration) -> Loaded {
        let entry = Self::entry_for(name, &model, compile_time);
        Loaded { entry, model, evicted: Vec::new(), freshly_compiled: false }
    }

    /// The tier pick: estimate (when a threshold is set) and either
    /// compile exactly or keep the raw network for the approximate tier.
    fn compile_model(&self, net: Network) -> Result<Compiled> {
        if self.max_exact_cost.is_finite() || self.max_exact_cost <= 0.0 {
            let cost = crate::jt::tree::estimate_cost(&net, TriangulationHeuristic::MinFill);
            if cost > self.max_exact_cost {
                return Ok(Compiled::Approx { net: Arc::new(net), cost });
            }
        }
        Ok(Compiled::Exact(Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?)))
    }

    /// Load `spec`, compiling its junction tree unless already resident.
    ///
    /// The registry key is the *network's* name, so `LOAD asia` and
    /// `LOAD path/to/asia.bif` coalesce onto one tree. Compilation happens
    /// outside the registry lock; a concurrent duplicate load keeps the
    /// first tree that registered.
    pub fn load(&self, spec: &str) -> Result<Loaded> {
        self.load_with(spec, || resolve_spec(spec))
    }

    /// [`Registry::load`] with the network pre-resolved by the caller —
    /// the fleet uses this to run minutes-long resolves (learning)
    /// *outside* its load lock and hand the finished network in. All
    /// cache fast paths, the learn-spec provenance guard, and eviction
    /// semantics are identical; a racing duplicate keeps the first tree.
    pub fn install(&self, spec: &str, net: Network) -> Result<Loaded> {
        self.load_with(spec, move || Ok(net))
    }

    /// The resident network name `spec` would hit **without any work**:
    /// `spec` itself if resident, or its recorded alias target. `None`
    /// means a load of `spec` would actually resolve (and maybe
    /// compile). Lets the fleet decide, before taking its load lock,
    /// whether a learn spec actually needs its pipeline run.
    pub fn resident_name_for(&self, spec: &str) -> Option<String> {
        let inner = self.inner.lock().unwrap();
        if inner.nets.contains_key(spec) {
            return Some(spec.to_string());
        }
        inner.aliases.get(spec).filter(|n| inner.nets.contains_key(*n)).cloned()
    }

    fn load_with(&self, spec: &str, resolve: impl FnOnce() -> Result<Network>) -> Result<Loaded> {
        // Fast paths: the spec is a resident name, or a spec we have
        // already resolved (a path) aliased onto a resident name — either
        // way the file is not re-read.
        if let Some((model, ct)) = self.lookup(spec) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Self::cache_hit(spec, model, ct));
        }
        if let Some(name) = self.inner.lock().unwrap().aliases.get(spec).cloned() {
            if let Some((model, ct)) = self.lookup(&name) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Self::cache_hit(&name, model, ct));
            }
        }
        // A `learn:` spec carries its provenance (samples/seed/base) in
        // the spec itself, so compile-once must NOT serve a resident of
        // *different* provenance under it: resolving would run the whole
        // learning pipeline only to discard the result, alias this spec
        // onto a net it did not produce, and (through the cluster front)
        // let the hand-off directory diverge from the served network.
        // Exact-spec repeats were already answered by the alias fast path
        // above; anything else hitting a resident name is refused. The
        // fleet serializes load/evict, so this check cannot race a
        // same-name load behind `Fleet::load`.
        if crate::learn::is_learn_spec(spec) {
            let name = crate::learn::LearnSpec::parse(spec)?.name;
            if self.inner.lock().unwrap().nets.contains_key(&name) {
                return Err(crate::Error::msg(format!(
                    "network {name:?} is already resident from a different spec; EVICT {name} to relearn"
                )));
            }
        }
        let net = resolve()?;
        let name = net.name.clone();
        if name != spec {
            self.inner.lock().unwrap().aliases.insert(spec.to_string(), name.clone());
        }
        if let Some((model, ct)) = self.lookup(&name) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Self::cache_hit(&name, model, ct));
        }
        let t0 = Instant::now();
        let model = self.compile_model(net)?;
        let compile_time = t0.elapsed();

        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.nets.get(&name) {
            // a concurrent load won the race; keep its model
            let (model, ct) = (r.model.clone(), r.compile_time);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Self::cache_hit(&name, model, ct));
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.nets.insert(name.clone(), Resident { model: model.clone(), compile_time, last_used: stamp });
        let mut evicted = Vec::new();
        while inner.nets.len() > self.capacity {
            let oldest = inner
                .nets
                .iter()
                .filter(|(k, _)| **k != name)
                .min_by_key(|(_, r)| r.last_used)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    inner.nets.remove(&k);
                    inner.aliases.retain(|_, target| *target != k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    evicted.push(k);
                }
                None => break,
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let entry = Self::entry_for(&name, &model, compile_time);
        Ok(Loaded { entry, model, evicted, freshly_compiled: true })
    }

    /// Resident model + its compile time, refreshing the LRU stamp.
    fn lookup(&self, name: &str) -> Option<(Compiled, Duration)> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        inner.nets.get_mut(name).map(|r| {
            r.last_used = stamp;
            (r.model.clone(), r.compile_time)
        })
    }

    /// Look a resident model up by name, refreshing its LRU stamp.
    pub fn get(&self, name: &str) -> Option<Compiled> {
        self.lookup(name).map(|(model, _)| model)
    }

    /// Drop a resident network (and any path aliases onto it). Returns
    /// whether it was resident. The cluster tier's `EVICT` hand-off verb
    /// lands here: after ownership moves to another backend process, the
    /// old owner frees the tree instead of serving a stale copy.
    pub fn remove(&self, name: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let existed = inner.nets.remove(name).is_some();
        if existed {
            inner.aliases.retain(|_, target| *target != name);
        }
        existed
    }

    /// Names of resident networks, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().nets.keys().cloned().collect()
    }

    /// Accounting snapshot of every resident network, sorted by name.
    pub fn entries(&self) -> Vec<RegistryEntry> {
        let inner = self.inner.lock().unwrap();
        inner.nets.iter().map(|(name, r)| Self::entry_for(name, &r.model, r.compile_time)).collect()
    }

    /// Number of resident networks.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().nets.len()
    }

    /// True when nothing is loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_compiles_once_and_caches() {
        let reg = Registry::new(4);
        let a = reg.load("asia").unwrap();
        assert_eq!(a.entry.name, "asia");
        assert!(a.freshly_compiled);
        assert!(a.entry.entries > 0);
        assert_eq!(a.entry.tier, Tier::Exact);
        assert!(a.entry.cost.is_none());
        assert!(a.model.jt().is_some());
        let b = reg.load("asia").unwrap();
        assert!(!b.freshly_compiled);
        // cache hits report the original compile accounting
        assert_eq!(b.entry.compile_time, a.entry.compile_time);
        assert!(a.model.same(&b.model));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn cost_threshold_routes_loads_by_tier() {
        // asia's exact cost is tiny, so a generous threshold keeps it exact
        let reg = Registry::with_max_exact_cost(4, 1e6);
        let a = reg.load("asia").unwrap();
        assert_eq!(a.entry.tier, Tier::Exact);
        assert!(a.entry.cliques > 0);
        // the intractable fixture blows past any sane threshold and falls
        // back to the approximate tier: raw net kept, nothing materialized
        let i = reg.load("intractable-sim").unwrap();
        assert_eq!(i.entry.tier, Tier::Approx);
        assert!(i.model.is_approx());
        assert_eq!((i.entry.cliques, i.entry.entries), (0, 0));
        assert!(i.entry.cost.unwrap() > 1e6, "{:?}", i.entry.cost);
        assert_eq!(i.model.net().name, "intractable-sim");
        // cache hits keep the tier decision
        let again = reg.load("intractable-sim").unwrap();
        assert!(!again.freshly_compiled);
        assert_eq!(again.entry.tier, Tier::Approx);
        assert!(again.model.same(&i.model));
        // threshold <= 0 forces even trivial nets approximate
        let always = Registry::with_max_exact_cost(4, 0.0);
        let a = always.load("asia").unwrap();
        assert_eq!(a.entry.tier, Tier::Approx);
        assert!(a.entry.cost.unwrap() > 0.0);
    }

    #[test]
    fn unknown_spec_errors() {
        let reg = Registry::new(4);
        assert!(reg.load("no-such-network").is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let reg = Registry::new(2);
        reg.load("asia").unwrap();
        reg.load("cancer").unwrap();
        // touch asia so cancer becomes the LRU entry
        assert!(reg.get("asia").is_some());
        let l = reg.load("sprinkler").unwrap();
        assert_eq!(l.evicted, vec!["cancer".to_string()]);
        assert_eq!(reg.names(), vec!["asia".to_string(), "sprinkler".to_string()]);
        // evicted networks can be reloaded (recompiled)
        assert!(reg.load("cancer").unwrap().freshly_compiled);
    }

    #[test]
    fn lru_counters_track_hits_misses_and_evictions() {
        let reg = Registry::new(2);
        assert_eq!(reg.lru_counters(), (0, 0, 0));
        reg.load("asia").unwrap(); // miss
        reg.load("asia").unwrap(); // hit (resident-name fast path)
        reg.load("cancer").unwrap(); // miss
        reg.load("sprinkler").unwrap(); // miss + evicts asia
        assert_eq!(reg.lru_counters(), (1, 3, 1));
        // explicit remove is not an eviction
        assert!(reg.remove("cancer"));
        assert_eq!(reg.lru_counters(), (1, 3, 1));
        let (h, m, e) = reg.lru_counter_handles();
        assert_eq!(
            (h.load(Ordering::Relaxed), m.load(Ordering::Relaxed), e.load(Ordering::Relaxed)),
            reg.lru_counters()
        );
    }

    #[test]
    fn path_specs_alias_onto_the_network_name() {
        let path = std::env::temp_dir().join(format!("fastbn-registry-{}.bif", std::process::id()));
        std::fs::write(&path, crate::bn::bif::write(&crate::bn::embedded::asia())).unwrap();
        let reg = Registry::new(4);
        let spec = path.to_str().unwrap();
        let a = reg.load(spec).unwrap();
        assert_eq!(a.entry.name, "asia");
        assert!(a.freshly_compiled);
        // the second load by the same path is an alias hit — cached tree,
        // no re-read — and loading by the bare name hits the same entry
        let b = reg.load(spec).unwrap();
        assert!(!b.freshly_compiled);
        assert!(a.model.same(&b.model));
        assert!(!reg.load("asia").unwrap().freshly_compiled);
        assert_eq!(reg.len(), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn remove_drops_the_tree_and_its_aliases() {
        let path = std::env::temp_dir().join(format!("fastbn-registry-rm-{}.bif", std::process::id()));
        std::fs::write(&path, crate::bn::bif::write(&crate::bn::embedded::asia())).unwrap();
        let reg = Registry::new(4);
        let spec = path.to_str().unwrap();
        reg.load(spec).unwrap();
        assert!(reg.remove("asia"));
        assert!(reg.get("asia").is_none());
        assert!(!reg.remove("asia")); // idempotent: already gone
        // the alias died with the entry: reloading by path recompiles
        assert!(reg.load(spec).unwrap().freshly_compiled);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn learn_specs_refuse_resident_names_of_different_provenance() {
        let reg = Registry::new(4);
        let a = reg.load("learn:l1:500:7:sprinkler").unwrap();
        assert_eq!(a.entry.name, "l1");
        assert!(a.freshly_compiled);
        // exact repeat: alias fast path, cached tree, no re-learn
        let b = reg.load("learn:l1:500:7:sprinkler").unwrap();
        assert!(!b.freshly_compiled);
        assert!(a.model.same(&b.model));
        // same name, different provenance: refused (never aliased, never
        // learned-and-discarded) — the served net and any recorded spec
        // cannot diverge
        let err = reg.load("learn:l1:500:8:sprinkler").unwrap_err();
        assert!(err.to_string().contains("already resident"), "{err}");
        assert!(reg.get("l1").unwrap().same(&a.model));
        // and the refused spec gained no alias: evicting frees the name
        // for a genuine relearn under the new spec
        assert!(reg.remove("l1"));
        assert!(reg.load("learn:l1:500:8:sprinkler").unwrap().freshly_compiled);
    }

    #[test]
    fn entries_report_size_and_compile_time() {
        let reg = Registry::new(4);
        reg.load("asia").unwrap();
        let e = &reg.entries()[0];
        assert_eq!(e.name, "asia");
        assert_eq!(e.cliques, 6);
        assert!(e.entries > 0);
    }
}
