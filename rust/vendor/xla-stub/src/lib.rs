//! Offline API stub for the `xla` crate (xla-rs).
//!
//! This container has no crates.io access, so the real `xla` crate (which
//! additionally needs a downloaded `xla_extension` C++ bundle) cannot be
//! fetched. This stub mirrors the exact API surface `fastbn::runtime::pjrt`
//! uses so that `cargo build --features xla` compiles everywhere; every
//! entry point fails at *runtime* with [`Error::StubOnly`].
//!
//! To run the real PJRT path, replace this dependency with the published
//! crate, e.g. in `rust/Cargo.toml`:
//!
//! ```toml
//! [patch.crates-io]        # or edit the dependency directly
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```
//!
//! The `fastbn` integration tests skip themselves (with a notice) when the
//! backend fails to come up, so `cargo test --features xla` — and
//! `make test-xla`, which builds artifacts first — stay green against this
//! stub; only swapping in the real crate makes them exercise PJRT.

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub enum Error {
    /// The only error this stub produces.
    StubOnly,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "xla stub: built against the offline API stub; link the real xla crate \
             (see rust/vendor/xla-stub) to execute PJRT"
        )
    }
}

impl std::error::Error for Error {}

fn stub<T>() -> Result<T, Error> {
    Err(Error::StubOnly)
}

/// PJRT client handle (stub).
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<Self, Error> {
        stub()
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub()
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        stub()
    }
}

/// An XLA computation (stub).
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a proto as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on device buffers. Always fails in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub()
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub()
    }
}

/// A host literal (stub).
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions. Always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub()
    }

    /// Extract the sole element of a 1-tuple. Always fails in the stub.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        stub()
    }

    /// Extract all elements of a tuple. Always fails in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        stub()
    }

    /// Copy out as a typed host vector. Always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        stub()
    }
}
