//! Bench: the **serving fleet** — shard count × network count sweep.
//!
//! Clients issue queries round-robin across every loaded network while the
//! router spreads each network's load over its shard group. The sweep
//! separates two scaling axes:
//!
//! 1. *Shards per network*: one network, shards ∈ {1, 2, 4} — replica
//!    scaling for a single hot tree.
//! 2. *Network count*: fleets hosting 1/2/4 networks at 2 shards each —
//!    does co-hosting trees degrade per-network latency?
//!
//! Scale knobs: FASTBN_FLEET_QUERIES (default 200 per cell),
//! FASTBN_FLEET_CLIENTS (default 4 concurrent client threads).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use fastbn::bench::{env_usize, fmt_duration, print_table};
use fastbn::bn::resolve_spec;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::evidence::Evidence;

/// Run `n_queries` through a fleet from `n_clients` threads, round-robin
/// across the loaded nets; returns (wall seconds, total served).
fn drive(fleet: &Arc<Fleet>, nets: &[&str], cases: &[Vec<Evidence>], n_queries: usize, n_clients: usize) -> (f64, u64) {
    let cursor = AtomicUsize::new(0);
    let served = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..n_clients.max(1) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n_queries {
                    break;
                }
                let net_i = i % nets.len();
                let ev = &cases[net_i][i % cases[net_i].len()];
                if fleet.query(nets[net_i], ev.clone()).is_ok() {
                    served.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    (t0.elapsed().as_secs_f64(), served.load(Ordering::Relaxed) as u64)
}

fn build_fleet(nets: &[&str], shards: usize) -> (Arc<Fleet>, Vec<Vec<Evidence>>) {
    let fleet = Arc::new(Fleet::new(FleetConfig {
        engine: EngineKind::Hybrid,
        engine_cfg: EngineConfig::default().with_threads(2),
        shards,
        registry_capacity: nets.len().max(1),
        max_exact_cost: f64::INFINITY,
    }));
    let mut cases = Vec::new();
    for (i, name) in nets.iter().enumerate() {
        fleet.load(name).unwrap();
        let net = resolve_spec(name).unwrap();
        cases.push(generate(&net, &CaseSpec { n_cases: 64, observed_fraction: 0.2, seed: 0xF1EE7 + i as u64 }));
    }
    (fleet, cases)
}

fn percentile_row(fleet: &Fleet) -> (String, String) {
    let snaps = fleet.metrics().snapshot();
    let p50 = snaps.iter().map(|s| s.latency.p50).max().unwrap_or_default();
    let p99 = snaps.iter().map(|s| s.latency.p99).max().unwrap_or_default();
    (fmt_duration(p50), fmt_duration(p99))
}

fn main() {
    let n_queries = env_usize("FASTBN_FLEET_QUERIES", 200);
    let n_clients = env_usize("FASTBN_FLEET_CLIENTS", 4);

    // ---- 1. shard scaling on one hot network ----
    let mut rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let (fleet, cases) = build_fleet(&["hailfinder-sim"], shards);
        let (wall, served) = drive(&fleet, &["hailfinder-sim"], &cases, n_queries, n_clients);
        let (p50, p99) = percentile_row(&fleet);
        rows.push(vec![
            format!("{shards}"),
            format!("{served}"),
            format!("{wall:.3}s"),
            format!("{:.1}", served as f64 / wall.max(1e-9)),
            p50,
            p99,
        ]);
    }
    print_table(
        &format!("fleet 1: shards per net (hailfinder-sim, {n_clients} clients, {n_queries} queries)"),
        &["shards", "served", "wall", "q/s", "p50(worst net)", "p99(worst net)"],
        &rows,
    );

    // ---- 2. network count at fixed shards ----
    let net_sets: [&[&str]; 3] =
        [&["asia"], &["asia", "cancer"], &["asia", "cancer", "sprinkler", "mixed12"]];
    let mut rows = Vec::new();
    for nets in net_sets {
        let (fleet, cases) = build_fleet(nets, 2);
        let (wall, served) = drive(&fleet, nets, &cases, n_queries, n_clients);
        let (p50, p99) = percentile_row(&fleet);
        rows.push(vec![
            format!("{}", nets.len()),
            format!("{served}"),
            format!("{wall:.3}s"),
            format!("{:.1}", served as f64 / wall.max(1e-9)),
            p50,
            p99,
        ]);
    }
    print_table(
        &format!("fleet 2: co-hosted networks (2 shards each, {n_clients} clients, {n_queries} queries)"),
        &["nets", "served", "wall", "q/s", "p50(worst net)", "p99(worst net)"],
        &rows,
    );
}
