//! Bench: **table-op backends** — native Rust loops vs the AOT-compiled
//! XLA artifacts through PJRT, over the bucket ladder.
//!
//! This is the L1/L2 integration benchmark: it locates the table size at
//! which PJRT dispatch overhead amortizes (on CPU the native loops win
//! below that). Skips with a notice if `artifacts/` is not built.

#[cfg(not(feature = "xla"))]
fn main() {
    println!("table_ops bench compares the XLA backend; rebuild with `--features xla` to run it");
}

#[cfg(feature = "xla")]
fn main() {
    use fastbn::bench::{print_table, Bench};
    use fastbn::rng::Rng;
    use fastbn::runtime::artifacts_available;
    use fastbn::runtime::ops::{NativeOps, TableOps2d, XlaOps};

    let dir = fastbn::runtime::artifact_dir();
    if !artifacts_available(&dir) {
        println!("artifacts/ not built — run `make artifacts` first; skipping table_ops bench");
        return;
    }
    let mut xla = match XlaOps::load(&dir) {
        Ok(x) => x,
        Err(e) => {
            println!("XLA backend unavailable ({e}); skipping table_ops bench");
            return;
        }
    };
    let mut native = NativeOps;
    let bench = Bench::new(3, 10);
    let mut rng = Rng::new(0xBE);

    let shapes: Vec<(usize, usize)> = vec![(16, 16), (64, 64), (256, 256), (1024, 256), (1024, 1024)];
    let mut rows = Vec::new();
    for (m, k) in shapes {
        if !xla.fits(m, k) {
            continue;
        }
        let table: Vec<f64> = (0..m * k).map(|_| rng.f64()).collect();
        let sep_new: Vec<f64> = (0..m).map(|_| rng.f64()).collect();
        let sep_old: Vec<f64> = (0..m).map(|_| rng.f64() + 0.1).collect();
        let mut out = vec![0.0; m];

        let marg_native = bench.run(|| {
            native.marginalize(&table, m, k, &mut out).unwrap();
        });
        let marg_xla = bench.run(|| {
            xla.marginalize(&table, m, k, &mut out).unwrap();
        });
        let mut t = table.clone();
        let abs_native = bench.run(|| {
            native.absorb(&mut t, m, k, &sep_new, &sep_old).unwrap();
        });
        let mut t2 = table.clone();
        let abs_xla = bench.run(|| {
            xla.absorb(&mut t2, m, k, &sep_new, &sep_old).unwrap();
        });

        rows.push(vec![
            format!("{m}x{k}"),
            format!("{:.1}µs", marg_native.mean.as_secs_f64() * 1e6),
            format!("{:.1}µs", marg_xla.mean.as_secs_f64() * 1e6),
            format!("{:.2}", marg_xla.mean.as_secs_f64() / marg_native.mean.as_secs_f64()),
            format!("{:.1}µs", abs_native.mean.as_secs_f64() * 1e6),
            format!("{:.1}µs", abs_xla.mean.as_secs_f64() * 1e6),
            format!("{:.2}", abs_xla.mean.as_secs_f64() / abs_native.mean.as_secs_f64()),
        ]);
    }
    print_table(
        "table-op backends: native loops vs AOT XLA via PJRT (mean of 10)",
        &["shape", "marg-nat", "marg-xla", "ratio", "absorb-nat", "absorb-xla", "ratio"],
        &rows,
    );
    println!("\nratio < 1 means the XLA artifact beats the native loop at that size;");
    println!("PJRT dispatch (+pad/copy) dominates small tables — see EXPERIMENTS.md.");

    // batched dispatch amortization: B same-bucket ops in one PJRT call
    let mut rows = Vec::new();
    for (b, m, k) in xla.batched_buckets() {
        let tables: Vec<f64> = (0..b * m * k).map(|_| rng.f64()).collect();
        let single = bench.run(|| {
            let mut out = vec![0.0; m];
            for i in 0..b {
                xla.marginalize(&tables[i * m * k..(i + 1) * m * k], m, k, &mut out).unwrap();
            }
        });
        let batched = bench.run(|| {
            xla.marginalize_batch(&tables, b, m, k).unwrap();
        });
        rows.push(vec![
            format!("{b}x{m}x{k}"),
            format!("{:.1}µs", single.mean.as_secs_f64() * 1e6),
            format!("{:.1}µs", batched.mean.as_secs_f64() * 1e6),
            format!("{:.2}", single.mean.as_secs_f64() / batched.mean.as_secs_f64()),
        ]);
    }
    if !rows.is_empty() {
        print_table(
            "batched dispatch: B single marg calls vs one (B,M,K) call",
            &["shape", "B singles", "batched", "amortization"],
            &rows,
        );
    }
}
