//! Bench: **ablations** of the design choices DESIGN.md calls out.
//!
//! 1. *Root selection* (paper §2): tree-center root vs naive first root —
//!    layer counts (structural, exact) + modeled hybrid time at t=16 +
//!    real measured sequential time (root affects only message order
//!    sequentially, so measured Δ should be ≈0 — separating structural
//!    from execution effects).
//! 2. *Index-mapping strategy* (the "bottleneck simplification"): cached
//!    per-edge maps vs odometer vs per-entry div/mod — real measured, the
//!    heart of the Fast-BNI-seq vs UnBBayes gap.
//! 3. *Flattening chunk size*: hybrid min_chunk sweep (modeled at t=16),
//!    now with **measured pool-region entries per sweep** — the B2 finish
//!    folds into single-chunk B1 tasks, so small min_chunk values pay a
//!    fourth region per layer that the default avoids; this is the data
//!    the `min_chunk` default can be revisited with (ROADMAP perf item).
//! 4. *Case-level replicas* (extension beyond the paper): real measured
//!    throughput at replicas ∈ {1, 2, 4} on this host.
//!
//! Scale knobs: FASTBN_CASES (default 10).

use std::sync::Arc;

use fastbn::bench::{env_usize, print_table, Bench};
use fastbn::bn::netgen;
use fastbn::coordinator::{BatchConfig, BatchRunner};
use fastbn::engine::hybrid::HybridEngine;
use fastbn::engine::simulate::{simulate_seconds, CostModel};
use fastbn::engine::{Engine, EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::propagate::MapMode;
use fastbn::jt::schedule::{RootStrategy, Schedule};
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn main() {
    let n_cases = env_usize("FASTBN_CASES", 10);
    let model = CostModel::calibrate();
    let bench = Bench::new(1, 3);

    // ---- 1. root selection ----
    let mut rows = Vec::new();
    for spec in netgen::paper_suite() {
        let net = spec.generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let center = Schedule::build(&jt, RootStrategy::Center);
        let first = Schedule::build(&jt, RootStrategy::First);
        let cfg_center = EngineConfig { root_strategy: RootStrategy::Center, ..Default::default() };
        let cfg_first = EngineConfig { root_strategy: RootStrategy::First, ..Default::default() };
        let m_center = simulate_seconds(EngineKind::Hybrid, &jt, 16, &cfg_center, &model);
        let m_first = simulate_seconds(EngineKind::Hybrid, &jt, 16, &cfg_first, &model);
        rows.push(vec![
            spec.name.clone(),
            format!("{}", center.height()),
            format!("{}", first.height()),
            format!("{:.3}ms", m_center * 1e3),
            format!("{:.3}ms", m_first * 1e3),
            format!("{:.2}", m_first / m_center),
        ]);
    }
    print_table(
        "ablation 1: root selection (layers exact; times modeled hybrid t=16)",
        &["BN", "layers(center)", "layers(first)", "hybrid(center)", "hybrid(first)", "gain"],
        &rows,
    );

    // ---- 2. index-mapping strategy (real measured, sequential) ----
    let mut rows = Vec::new();
    for name in ["hailfinder-sim", "pigs-sim", "munin2-sim"] {
        let net = netgen::paper_net(name).unwrap();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = generate(&net, &CaseSpec { n_cases, observed_fraction: 0.2, seed: 0xAB });
        let mut row = vec![name.to_string()];
        let mut cached_s = 0.0;
        for mode in [MapMode::Cached, MapMode::Odometer, MapMode::DivMod] {
            let cfg = EngineConfig { map_mode: mode, threads: 1, ..Default::default() };
            let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let stat = bench.run(|| {
                for ev in &cases {
                    let _ = engine.infer(&mut state, ev);
                }
            });
            if matches!(mode, MapMode::Cached) {
                cached_s = stat.mean.as_secs_f64();
            }
            row.push(format!("{:.3}s", stat.mean.as_secs_f64()));
        }
        let divmod_s: f64 = row[3].trim_end_matches('s').parse().unwrap();
        row.push(format!("{:.2}x", divmod_s / cached_s));
        rows.push(row);
    }
    print_table(
        &format!("ablation 2: index-mapping strategy (measured, seq, {n_cases} cases)"),
        &["BN", "cached", "odometer", "divmod", "divmod/cached"],
        &rows,
    );

    // ---- 3. hybrid chunk-size sweep (modeled t=16) + measured region
    //         entries per sweep (exact — counted by the engine itself)
    let mut rows = Vec::new();
    for name in ["pigs-sim", "munin4-sim"] {
        let net = netgen::paper_net(name).unwrap();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut row = vec![name.to_string()];
        for min_chunk in [64usize, 512, 2048, 8192, 65536] {
            let cfg = EngineConfig { min_chunk, ..Default::default() };
            let s = simulate_seconds(EngineKind::Hybrid, &jt, 16, &cfg, &model);
            // pool regions actually entered by one sweep at this chunking
            let mut engine = HybridEngine::new(Arc::clone(&jt), &cfg.clone().with_threads(2));
            let mut state = TreeState::fresh(&jt);
            let _ = engine.infer(&mut state, &fastbn::jt::evidence::Evidence::none());
            row.push(format!("{:.3}ms/{}r", s * 1e3, engine.pool_regions()));
        }
        rows.push(row);
    }
    print_table(
        "ablation 3: hybrid chunk size (modeled per-case t=16 / measured pool regions per sweep)",
        &["BN", "chunk=64", "512", "2048", "8192", "65536"],
        &rows,
    );

    // ---- 4. case-level replicas (real measured) ----
    let mut rows = Vec::new();
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: n_cases * 10, observed_fraction: 0.2, seed: 0xAC });
    let runner = BatchRunner::new(Arc::clone(&jt));
    for replicas in [1usize, 2, 4] {
        let cfg = BatchConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            replicas,
            fused_batch: 0,
        };
        let report = runner.run(&cases, &cfg).unwrap();
        rows.push(vec![
            format!("{replicas}"),
            format!("{:?}", report.wall),
            format!("{:.1}", report.throughput()),
        ]);
    }
    print_table(
        "ablation 4: case-level replicas (measured; 1 core => flat is expected)",
        &["replicas", "wall", "cases/s"],
        &rows,
    );
}
