//! Bench: the **cluster tier** — backend-count and replica-count sweeps
//! through the front router.
//!
//! Every query crosses two TCP hops (client → front tier → owning
//! backend), so this measures what the cluster actually adds over an
//! in-process fleet: routing, proxying, and socket overhead, and how
//! throughput scales as the same network set spreads over 1/2/4 backend
//! processes. One client per network holds a sticky session (`USE` once,
//! then inline-evidence `QUERY`s), matching the serving shape. Those
//! sessions carry no committed evidence, so with `replicas > 1` the
//! front round-robins their reads across the owner set — the second
//! table sweeps R at a fixed backend count to price replication against
//! the single-owner baseline.
//!
//! Scale knob: FASTBN_CLUSTER_QUERIES (default 200 per cell, split
//! evenly across the nets' clients).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use fastbn::bench::{env_usize, fmt_duration, print_table};
use fastbn::bn::resolve_spec;
use fastbn::cluster::harness::query_line;
use fastbn::cluster::{ClusterClient, ClusterConfig, ClusterHarness};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::FleetConfig;
use fastbn::infer::cases::{generate, CaseSpec};

const NETS: [&str; 4] = ["asia", "cancer", "sprinkler", "mixed12"];

fn harness(n_backends: usize, replicas: usize) -> ClusterHarness {
    let backend_cfg = FleetConfig {
        engine: EngineKind::Hybrid,
        engine_cfg: EngineConfig::default().with_threads(2),
        shards: 2,
        registry_capacity: NETS.len(),
        max_exact_cost: f64::INFINITY,
    };
    let cluster_cfg = ClusterConfig { replicas, ..ClusterConfig::default() };
    let harness = ClusterHarness::start(n_backends, backend_cfg, cluster_cfg).unwrap();
    let mut client = harness.client().unwrap();
    for net in NETS {
        let reply = client.request(&format!("LOAD {net}")).unwrap();
        assert!(reply.starts_with("OK loaded"), "{reply}");
    }
    harness
}

/// One sticky client per net, `per_net` queries each; returns
/// (wall seconds, served).
fn drive(harness: &ClusterHarness, cases: &[(String, Vec<String>)], per_net: usize) -> (f64, u64) {
    let served = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for (net, lines) in cases {
            let served = &served;
            let front = harness.front_addr();
            scope.spawn(move || {
                let mut client = ClusterClient::connect(front).unwrap();
                assert!(client.request(&format!("USE {net}")).unwrap().starts_with("OK using"));
                for i in 0..per_net {
                    if client.request(&lines[i % lines.len()]).unwrap().starts_with("OK") {
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    (t0.elapsed().as_secs_f64(), served.load(Ordering::Relaxed))
}

fn main() {
    let n_queries = env_usize("FASTBN_CLUSTER_QUERIES", 200);
    let per_net = (n_queries / NETS.len()).max(1);

    // pre-render the protocol lines once; the bench then measures
    // serving, not formatting
    let cases: Vec<(String, Vec<String>)> = NETS
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let net = resolve_spec(name).unwrap();
            let target = net.vars[net.n() - 1].name.clone();
            let evs = generate(&net, &CaseSpec { n_cases: 32, observed_fraction: 0.2, seed: 0xC105 + i as u64 });
            (name.to_string(), evs.iter().map(|ev| query_line(&net, &target, ev)).collect())
        })
        .collect();

    let mut rows = Vec::new();
    let mut last_topo = String::new();
    for n_backends in [1usize, 2, 4] {
        let h = harness(n_backends, 1);
        let (wall, served) = drive(&h, &cases, per_net);
        let total = (per_net * NETS.len()) as u64;
        rows.push(vec![
            format!("{n_backends}"),
            format!("{}", NETS.len()),
            format!("{served}/{total}"),
            format!("{wall:.3}s"),
            format!("{:.1}", served as f64 / wall.max(1e-9)),
            fmt_duration(std::time::Duration::from_secs_f64(wall / served.max(1) as f64)),
        ]);
        last_topo = h.client().unwrap().request("TOPO").unwrap();
    }
    print_table(
        &format!("cluster: backend-count sweep ({} nets, {per_net} queries/net, sticky sessions)", NETS.len()),
        &["backends", "nets", "served", "wall", "q/s", "mean/query"],
        &rows,
    );
    // ownership spread at the widest topology, for the record
    println!("\n{last_topo}");

    // replica sweep at a fixed backend count: R=1 is the single-owner
    // baseline; R>1 pays extra LOADs up front and then spreads each
    // clean session's reads over the owner set
    let mut rows = Vec::new();
    for (n_backends, replicas) in [(4usize, 1usize), (4, 2), (4, 4)] {
        let h = harness(n_backends, replicas);
        let (wall, served) = drive(&h, &cases, per_net);
        let total = (per_net * NETS.len()) as u64;
        rows.push(vec![
            format!("{n_backends}"),
            format!("{replicas}"),
            format!("{served}/{total}"),
            format!("{wall:.3}s"),
            format!("{:.1}", served as f64 / wall.max(1e-9)),
            fmt_duration(std::time::Duration::from_secs_f64(wall / served.max(1) as f64)),
        ]);
    }
    print_table(
        &format!("cluster: replica sweep ({} nets, {per_net} queries/net, read-spread sessions)", NETS.len()),
        &["backends", "replicas", "served", "wall", "q/s", "mean/query"],
        &rows,
    );
}
