//! Bench: **pool-parallel structure learning** — the thread-scaling sweep.
//!
//! PC-stable's levels are embarrassingly parallel batches of CI tests
//! (all tests of a level are independent once adjacency is frozen), so
//! skeleton discovery should scale with the worker pool the same way the
//! inference engines do. This bench learns from forward samples of
//! mid-size networks at t ∈ {1, 2, 4, 8} threads, reporting wall time,
//! CI-test counts, and tests/second — plus a determinism guard: every
//! thread count must produce the identical skeleton and CPDAG (the
//! contract the fleet's LEARN verb and the cluster hand-off rely on).
//!
//! Scale knobs: FASTBN_SAMPLES (default 20000 rows), FASTBN_LEARN_MAX_T
//! (default 8 — the top of the thread sweep).

use fastbn::bench::{env_usize, print_table, Bench};
use fastbn::bn::{embedded, netgen};
use fastbn::learn::{learn, Dataset, LearnConfig};

fn main() {
    let samples = env_usize("FASTBN_SAMPLES", 20_000);
    let max_t = env_usize("FASTBN_LEARN_MAX_T", 8).max(1);
    let threads: Vec<usize> = [1usize, 2, 4, 8].into_iter().filter(|&t| t <= max_t).collect();
    let bench = Bench::new(1, 3);

    let nets = vec![
        embedded::asia(),
        embedded::mixed12(),
        netgen::NetSpec {
            name: "learn-30".into(),
            nodes: 30,
            arcs: 40,
            max_parents: 2,
            card_choices: vec![(2, 0.7), (3, 0.3)],
            locality: 6,
            max_table: 1 << 10,
            alpha: 1.0,
            seed: 0x5EED,
        }
        .generate(),
    ];

    let mut rows = Vec::new();
    for net in &nets {
        let data = Dataset::from_network(net, samples, 0xBE9C);
        let mut row = vec![net.name.clone(), format!("{}x{}", data.n_rows(), data.n_vars())];
        let mut base = None;
        let mut t1_secs = 0.0f64;
        for &t in &threads {
            let cfg = LearnConfig::default().with_threads(t);
            // determinism guard across the sweep (and the data the table reports)
            let report = learn(&data, &net.name, &cfg).expect("learn");
            match &base {
                None => {
                    row.insert(2, format!("{}", report.ci_tests()));
                    row.insert(3, format!("{}", report.skeleton.len()));
                    base = Some((report.skeleton.clone(), report.compelled.clone()));
                }
                Some((skel, compelled)) => {
                    assert_eq!(&report.skeleton, skel, "{}: skeleton changed at t={t}", net.name);
                    assert_eq!(&report.compelled, compelled, "{}: CPDAG changed at t={t}", net.name);
                }
            }
            let stat = bench.run(|| {
                let _ = learn(&data, &net.name, &cfg).expect("learn");
            });
            let secs = stat.mean.as_secs_f64();
            if t == 1 {
                t1_secs = secs;
            }
            let tests_per_s = report.ci_tests() as f64 / secs;
            row.push(format!("{:.0}ms ({:.0}/s)", secs * 1e3, tests_per_s));
            if t == *threads.last().unwrap() {
                row.push(format!("{:.2}x", t1_secs / secs));
            }
        }
        rows.push(row);
    }

    let mut headers: Vec<String> = vec!["BN".into(), "rows".into(), "tests".into(), "edges".into()];
    headers.extend(threads.iter().map(|t| format!("t={t}")));
    headers.push("t1/tmax".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print_table(
        &format!("learn: PC-stable thread scaling ({samples} samples, alpha 0.01)"),
        &header_refs,
        &rows,
    );
    println!("\nacceptance: identical skeleton/CPDAG at every thread count; wall time drops with t");
}
