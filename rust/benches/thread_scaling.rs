//! Bench: **thread scaling** — the paper's prose observation that
//! "Fast-BNI always achieves its shortest execution time when t = 32 on
//! large BNs" while small networks saturate (or degrade) earlier.
//!
//! Modeled per-case times across t ∈ {1..32} per engine (cost model,
//! DESIGN.md §3), plus a real measured sanity section: hybrid at t = 1 vs
//! t = 2 on this single-core host (expected ≥ 1×: oversubscription — the
//! same region/task overheads the model's constants capture).
//!
//! Scale knobs: FASTBN_NETS (comma list; default hailfinder-sim,
//! pigs-sim, munin4-sim).

use std::sync::Arc;

use fastbn::bench::print_table;
use fastbn::bn::netgen;
use fastbn::engine::simulate::{simulate_seconds, CostModel};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn main() {
    let nets: Vec<String> = std::env::var("FASTBN_NETS")
        .map(|v| v.split(',').map(|s| s.to_string()).collect())
        .unwrap_or_else(|_| vec!["hailfinder-sim".into(), "pigs-sim".into(), "munin4-sim".into()]);
    let sweep = [1usize, 2, 4, 8, 16, 24, 32];

    println!("calibrating cost model...");
    let model = CostModel::calibrate();
    let cfg = EngineConfig::default();

    for name in &nets {
        let Some(net) = netgen::paper_net(name) else {
            eprintln!("skipping unknown paper net {name}");
            continue;
        };
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut rows = Vec::new();
        for kind in EngineKind::PARALLEL {
            let mut row = vec![kind.label().to_string()];
            let mut best = (0usize, f64::INFINITY);
            for &t in &sweep {
                let s = simulate_seconds(kind, &jt, t, &cfg, &model);
                if s < best.1 {
                    best = (t, s);
                }
                row.push(format!("{:.2}ms", s * 1e3));
            }
            row.push(format!("t={}", best.0));
            rows.push(row);
        }
        let headers: Vec<String> = std::iter::once("engine".to_string())
            .chain(sweep.iter().map(|t| format!("t={t}")))
            .chain(std::iter::once("best".to_string()))
            .collect();
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        print_table(&format!("modeled per-case time — {name} ({})", jt.stats()), &headers_ref, &rows);
    }

    // real measured sanity: oversubscription overhead on one core
    println!("\n== real measured sanity (single-core host) ==");
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: 50, observed_fraction: 0.2, seed: 3 });
    for t in [1usize, 2] {
        let mut eng = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default().with_threads(t));
        let mut state = TreeState::fresh(&jt);
        let t0 = std::time::Instant::now();
        for ev in &cases {
            let _ = eng.infer(&mut state, ev);
        }
        println!("hybrid measured, {} thread(s): {:?} for {} cases", t, t0.elapsed(), cases.len());
    }
}
