//! Bench: **case-major batched propagation** — the B-sweep.
//!
//! The batched engine amortizes every cached index-map lookup (and every
//! pool-region entry) across B evidence cases per sweep. This bench
//! measures per-case time at B ∈ {1, 4, 16, 64} on multi-clique networks
//! (acceptance: per-case time strictly decreasing from B=1 to B≥16), with
//! the sequential and hybrid engines as per-case baselines, and verifies a
//! sample of the batched answers against Fast-BNI-seq at ≤1e-9 so a
//! mis-measured kernel can't silently "win".
//!
//! Scale knobs: FASTBN_CASES (default 64 — the case-list length; keep it a
//! multiple of 64 so every B divides it), FASTBN_THREADS (default 0 = all
//! cores).

use std::sync::Arc;

use fastbn::bench::{env_usize, print_table, Bench};
use fastbn::bn::netgen;
use fastbn::engine::batched::BatchedHybridEngine;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

const B_SWEEP: [usize; 4] = [1, 4, 16, 64];

fn main() {
    let n_cases = env_usize("FASTBN_CASES", 64).max(B_SWEEP[B_SWEEP.len() - 1]);
    let threads = env_usize("FASTBN_THREADS", 0);
    let bench = Bench::new(1, 3);

    let mut rows = Vec::new();
    for name in ["hailfinder-sim", "pigs-sim", "munin2-sim"] {
        let net = netgen::paper_net(name).unwrap();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = generate(&net, &CaseSpec { n_cases, observed_fraction: 0.2, seed: 0xBA7C });
        let mut row = vec![name.to_string(), format!("{}", jt.n_cliques())];

        // per-case baselines: seq (1 thread) and hybrid (threads)
        {
            let cfg = EngineConfig { threads: 1, ..Default::default() };
            let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let stat = bench.run(|| {
                for ev in &cases {
                    let _ = seq.infer(&mut state, ev);
                }
            });
            row.push(format!("{:.1}µs", stat.mean.as_secs_f64() * 1e6 / cases.len() as f64));
        }
        {
            let cfg = EngineConfig { threads, ..Default::default() };
            let mut hyb = EngineKind::Hybrid.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let stat = bench.run(|| {
                for ev in &cases {
                    let _ = hyb.infer(&mut state, ev);
                }
            });
            row.push(format!("{:.1}µs", stat.mean.as_secs_f64() * 1e6 / cases.len() as f64));
        }

        // the B-sweep: per-case µs at each lane count
        let mut b1_per_case = 0.0f64;
        for b in B_SWEEP {
            let cfg = EngineConfig { threads, ..Default::default() }.with_batch(b);
            let mut eng = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
            let stat = bench.run(|| {
                let _ = eng.infer_cases(&cases);
            });
            let per_case = stat.mean.as_secs_f64() * 1e6 / cases.len() as f64;
            if b == 1 {
                b1_per_case = per_case;
            }
            row.push(format!("{per_case:.1}µs"));
            if b == B_SWEEP[B_SWEEP.len() - 1] {
                row.push(format!("{:.2}x", b1_per_case / per_case));
            }
        }
        rows.push(row);

        // correctness guard: a sample of batched answers vs seq at 1e-9
        let cfg = EngineConfig { threads, ..Default::default() }.with_batch(16);
        let mut eng = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
        let sample = &cases[..cases.len().min(16)];
        let got = eng.infer_cases(sample);
        let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig { threads: 1, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        for (i, (g, ev)) in got.iter().zip(sample).enumerate() {
            let want = seq.infer(&mut state, ev).unwrap();
            let d = g.as_ref().unwrap().max_abs_diff(&want);
            assert!(d <= 1e-9, "{name} case {i}: batched differs from seq by {d:e}");
        }
    }
    print_table(
        &format!("batch: per-case time vs lanes B ({n_cases} cases, threads={threads})"),
        &["BN", "cliques", "seq", "hybrid", "B=1", "B=4", "B=16", "B=64", "B1/B64"],
        &rows,
    );
    println!("\nacceptance: per-case time should decrease monotonically from B=1 to B>=16");
}
