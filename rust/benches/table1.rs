//! Bench: **Table 1** — sequential and parallel execution-time comparison
//! over the six network analogs.
//!
//! Sequential columns (UnBBayes vs Fast-BNI-seq) are measured wall-clock.
//! Parallel columns follow the paper's protocol — "varied the number of
//! threads t from 1 to 32 and chose the shortest" — through the
//! calibrated cost model (single-core container; DESIGN.md §3). The model
//! is validated in-run: modeled Fast-BNI-seq time at t=1 is printed next
//! to the measured time, and the ratio is reported.
//!
//! Scale knobs: FASTBN_CASES (default 12), FASTBN_NETS (comma list).

use std::sync::Arc;

use fastbn::bench::{env_usize, fmt_duration, print_table, Bench};
use fastbn::bn::netgen;
use fastbn::coordinator::{BatchConfig, BatchRunner};
use fastbn::engine::simulate::{best_over_threads, simulate_seconds, CostModel};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn main() {
    let n_cases = env_usize("FASTBN_CASES", 12);
    let filter: Option<Vec<String>> =
        std::env::var("FASTBN_NETS").ok().map(|v| v.split(',').map(|s| s.to_string()).collect());
    let sweep = [1usize, 2, 4, 8, 16, 32];
    let bench = Bench::new(0, 1); // batches are already N-case aggregates

    println!("calibrating cost model...");
    let model = CostModel::calibrate();
    println!("{model:?}");

    let mut rows = Vec::new();
    let mut validation = Vec::new();
    for spec in netgen::paper_suite() {
        if let Some(f) = &filter {
            if !f.contains(&spec.name) {
                continue;
            }
        }
        let net = spec.generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = generate(&net, &CaseSpec { n_cases, observed_fraction: 0.2, seed: 0x7AB1 });
        let runner = BatchRunner::new(Arc::clone(&jt));
        eprintln!("[{}] {}", spec.name, jt.stats());

        let mut measured = std::collections::HashMap::new();
        for kind in [EngineKind::Unb, EngineKind::Seq] {
            let cfg = BatchConfig {
                engine: kind,
                engine_cfg: EngineConfig::default().with_threads(1),
                replicas: 1,
                fused_batch: 0,
            };
            let stat = bench.run(|| {
                runner.run(&cases, &cfg).unwrap();
            });
            measured.insert(kind, stat.mean);
        }

        // model validation: modeled seq time at t=1 vs measured
        let modeled_seq =
            simulate_seconds(EngineKind::Seq, &jt, 1, &EngineConfig::default(), &model) * n_cases as f64;
        let measured_seq = measured[&EngineKind::Seq].as_secs_f64();
        validation.push(vec![
            spec.name.clone(),
            format!("{measured_seq:.3}s"),
            format!("{modeled_seq:.3}s"),
            format!("{:.2}", modeled_seq / measured_seq),
        ]);

        let cfg = EngineConfig::default();
        let mut best: Vec<(EngineKind, usize, f64)> = EngineKind::PARALLEL
            .iter()
            .map(|&k| {
                let (t, s) = best_over_threads(k, &jt, &sweep, &cfg, &model);
                (k, t, s * n_cases as f64)
            })
            .collect();
        let hybrid = best.pop().unwrap(); // Hybrid is last in PARALLEL
        let unb = measured[&EngineKind::Unb].as_secs_f64();
        let seq = measured[&EngineKind::Seq].as_secs_f64();

        rows.push(vec![
            spec.name.clone(),
            fmt_duration(measured[&EngineKind::Unb]),
            fmt_duration(measured[&EngineKind::Seq]),
            format!("{:.1}", unb / seq),
            format!("{:.3}s", best[0].2),
            format!("{:.3}s", best[1].2),
            format!("{:.3}s", best[2].2),
            format!("{:.3}s", hybrid.2),
            format!("{:.1}", best[0].2 / hybrid.2),
            format!("{:.1}", best[1].2 / hybrid.2),
            format!("{:.1}", best[2].2 / hybrid.2),
            format!("{}", hybrid.1),
        ]);
    }

    print_table(
        &format!("Table 1 ({n_cases} cases; seq measured, par modeled best-t)"),
        &[
            "BN", "UnBBayes", "FBNI-seq", "spd", "Dir.", "Prim.", "Elem.", "FBNI-par", "spd-D", "spd-P",
            "spd-E", "best-t",
        ],
        &rows,
    );
    print_table(
        "cost-model validation (modeled vs measured Fast-BNI-seq, t = 1)",
        &["BN", "measured", "modeled", "ratio"],
        &validation,
    );
}
