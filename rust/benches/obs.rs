//! Bench: **observability overhead** — the query-path cost of the span
//! tracer and the pool parallelism profiler, armed vs disarmed. The
//! disarmed contract is "one relaxed load per query / per region entry";
//! this sweep puts a number on it, and on the armed collection cost the
//! `TRACE`/`PROFILE` verbs buy (two clock reads + two relaxed adds per
//! claimed task).
//!
//! Modes per net × thread count, hybrid engine: `off` (both toggles down
//! — the production default), `trace` (span recording into the global
//! ring), `profile` (per-task busy/task tallies in every pool region),
//! `both`. Overhead is each mode's mean latency over `off`'s.
//!
//! When `FASTBN_BENCH_JSON` names a path (`make bench-json` →
//! `BENCH_obs.json`) the sweep is written as JSON with a stable schema;
//! the CI perf-trajectory job shape-checks and uploads it on every push,
//! so telemetry-cost regressions show up as a trend across commits.
//!
//! Scale knobs: FASTBN_OBS_NETS (comma list, default asia,hailfinder-sim)
//! and FASTBN_OBS_THREADS (comma list, default 2).

use std::sync::Arc;

use fastbn::bench::{print_table, Bench};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;
use fastbn::obs::{profile, trace};

fn env_list(name: &str, default: &[&str]) -> Vec<String> {
    std::env::var(name)
        .ok()
        .map(|v| v.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect::<Vec<_>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
}

struct Point {
    net: String,
    threads: usize,
    mode: &'static str,
    mean_ms: f64,
    overhead_pct: f64,
}

/// (mode label, tracer enabled, profiler armed) — `off` must come first:
/// it is the baseline the other modes' overhead is computed against.
const MODES: [(&str, bool, bool); 4] =
    [("off", false, false), ("trace", true, false), ("profile", false, true), ("both", true, true)];

/// Render the perf-trajectory artifact. The schema is a contract: the CI
/// job diffs this shape against the committed `BENCH_obs.json`, so
/// additions must keep every existing key.
fn render_json(points: &[Point]) -> String {
    let mut out = String::from("{\n  \"bench\": \"obs\",\n  \"schema_version\": 1,\n");
    out.push_str("  \"provenance\": \"measured (cargo bench --bench obs)\",\n");
    out.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"net\": \"{}\", \"threads\": {}, \"mode\": \"{}\", \"mean_ms\": {:.4}, \"overhead_pct\": {:.1}}}{}\n",
            p.net,
            p.threads,
            p.mode,
            p.mean_ms,
            p.overhead_pct,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let nets = env_list("FASTBN_OBS_NETS", &["asia", "hailfinder-sim"]);
    let threads: Vec<usize> = env_list("FASTBN_OBS_THREADS", &["2"]).iter().filter_map(|t| t.parse().ok()).collect();
    let runner = Bench::new(3, 9);

    let mut points: Vec<Point> = Vec::new();
    let mut rows = Vec::new();
    for spec in &nets {
        let net = fastbn::bn::resolve_spec(spec).expect("resolvable net spec");
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).expect("net compiles"));
        let ev = Evidence::from_pairs(&net, &[(net.vars[0].name.as_str(), net.vars[0].states[0].as_str())])
            .expect("first variable's first state is valid evidence");
        for &t in &threads {
            let cfg = EngineConfig::default().with_threads(t);
            let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let mut base_ms = 0.0;
            for (mode, trace_on, profile_on) in MODES {
                trace::set_enabled(trace_on);
                profile::set_armed(profile_on);
                let stat = runner.run(|| {
                    let post = engine.infer(&mut state, &ev).expect("inference succeeds");
                    std::hint::black_box(post.log_z);
                });
                trace::set_enabled(false);
                profile::set_armed(false);
                if mode == "off" {
                    base_ms = stat.mean_ms();
                }
                let overhead_pct = if base_ms > 0.0 { (stat.mean_ms() / base_ms - 1.0) * 100.0 } else { 0.0 };
                rows.push(vec![
                    spec.clone(),
                    format!("{t}"),
                    mode.to_string(),
                    format!("{:.4}", stat.mean_ms()),
                    format!("{overhead_pct:+.1}%"),
                ]);
                points.push(Point { net: spec.clone(), threads: t, mode, mean_ms: stat.mean_ms(), overhead_pct });
            }
        }
    }
    print_table(
        "observability overhead — tracer/profiler armed vs disarmed (hybrid engine)",
        &["net", "threads", "mode", "mean_ms", "overhead"],
        &rows,
    );

    if let Ok(path) = std::env::var("FASTBN_BENCH_JSON") {
        std::fs::write(&path, render_json(&points)).expect("write bench JSON");
        println!("\nwrote {path}");
    }
}
