//! Bench: **approximate tier** — likelihood-weighting cost and accuracy
//! versus the exact hybrid engine, swept over sample counts × threads on
//! a small net (asia) and a paper-suite analog (hailfinder-sim).
//!
//! When `FASTBN_BENCH_JSON` names a path (`make bench-json` →
//! `BENCH_approx.json`) the sweep is also written as JSON with a stable
//! schema; the CI perf-trajectory job uploads it as an artifact on every
//! push, so regressions in the sampling tier show up as a trend across
//! commits rather than a surprise.
//!
//! Scale knobs: FASTBN_APPROX_SAMPLES (comma list, default
//! 10000,40000,100000) and FASTBN_APPROX_THREADS (comma list, default
//! 1,2,4).

use std::sync::Arc;

use fastbn::bench::{print_table, Bench};
use fastbn::bn::network::Network;
use fastbn::bn::{embedded, netgen};
use fastbn::engine::approx::ApproxEngine;
use fastbn::engine::{Engine, EngineConfig, EngineKind};
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn env_list(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect::<Vec<usize>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

struct SweepPoint {
    samples: usize,
    threads: usize,
    mean_ms: f64,
    max_abs_err: f64,
    ci95: f64,
    ess: f64,
}

struct NetReport {
    net: String,
    exact_ms: f64,
    points: Vec<SweepPoint>,
}

fn bench_net(net: Network, sample_counts: &[usize], threads: &[usize], runner: &Bench) -> NetReport {
    let net = Arc::new(net);
    let ev = Evidence::none();

    // exact baseline: the hybrid engine's posterior is the ground truth
    // the sweep's max|Δ| column is measured against
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cfg1 = EngineConfig::default().with_threads(1);
    let mut exact_engine = EngineKind::Hybrid.build(Arc::clone(&jt), &cfg1);
    let mut exact_state = TreeState::fresh(&jt);
    let exact = exact_engine.infer(&mut exact_state, &ev).unwrap();
    let exact_ms = runner
        .run(|| {
            let _ = exact_engine.infer(&mut exact_state, &ev).unwrap();
        })
        .mean_ms();

    let mut points = Vec::new();
    let mut rows = Vec::new();
    for &n in sample_counts {
        for &t in threads {
            let acfg = EngineConfig::default().with_threads(t).with_samples(n);
            let mut engine = ApproxEngine::from_net(Arc::clone(&net), &acfg);
            let mut state = TreeState::detached();
            let post = engine.infer(&mut state, &ev).unwrap();
            let stat = runner.run(|| {
                let _ = engine.infer(&mut state, &ev).unwrap();
            });
            let info = post.approx.as_ref().expect("approximate posteriors carry their info");
            let mut err = 0.0f64;
            for v in 0..net.n() {
                for s in 0..net.card(v) {
                    err = err.max((post.probs[v][s] - exact.probs[v][s]).abs());
                }
            }
            rows.push(vec![
                format!("{n}"),
                format!("{t}"),
                format!("{:.3}", stat.mean_ms()),
                format!("{err:.5}"),
                format!("{:.5}", info.max_half_width()),
                format!("{:.0}", info.effective_samples),
            ]);
            points.push(SweepPoint {
                samples: n,
                threads: t,
                mean_ms: stat.mean_ms(),
                max_abs_err: err,
                ci95: info.max_half_width(),
                ess: info.effective_samples,
            });
        }
    }
    rows.push(vec!["exact".into(), "1".into(), format!("{exact_ms:.3}"), "0.00000".into(), "-".into(), "-".into()]);
    print_table(
        &format!("likelihood weighting vs exact — {} ({} vars)", net.name, net.n()),
        &["samples", "threads", "mean_ms", "max|err|", "ci95", "ess"],
        &rows,
    );
    NetReport { net: net.name.clone(), exact_ms, points }
}

/// Render the perf-trajectory artifact. The schema is a contract: the CI
/// job diffs this shape against the committed `BENCH_approx.json`, so
/// additions must keep every existing key.
fn render_json(reports: &[NetReport]) -> String {
    let mut out = String::from("{\n  \"bench\": \"approx\",\n  \"schema_version\": 1,\n");
    out.push_str("  \"provenance\": \"measured (cargo bench --bench approx)\",\n  \"nets\": [\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str(&format!("    {{\"net\": \"{}\", \"exact_ms\": {:.4}, \"sweep\": [\n", r.net, r.exact_ms));
        for (j, p) in r.points.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"samples\": {}, \"threads\": {}, \"mean_ms\": {:.4}, \"max_abs_err\": {:.6}, \"ci95\": {:.6}, \"ess\": {:.0}}}{}\n",
                p.samples,
                p.threads,
                p.mean_ms,
                p.max_abs_err,
                p.ci95,
                p.ess,
                if j + 1 < r.points.len() { "," } else { "" }
            ));
        }
        out.push_str(&format!("    ]}}{}\n", if i + 1 < reports.len() { "," } else { "" }));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let sample_counts = env_list("FASTBN_APPROX_SAMPLES", &[10_000, 40_000, 100_000]);
    let threads = env_list("FASTBN_APPROX_THREADS", &[1, 2, 4]);
    let runner = Bench::default();

    let reports = vec![
        bench_net(embedded::asia(), &sample_counts, &threads, &runner),
        bench_net(netgen::paper_net("hailfinder-sim").unwrap(), &sample_counts, &threads, &runner),
    ];

    if let Ok(path) = std::env::var("FASTBN_BENCH_JSON") {
        std::fs::write(&path, render_json(&reports)).expect("write bench JSON");
        println!("\nwrote {path}");
    }
}
