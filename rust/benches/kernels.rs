//! Bench: **lane micro-kernels** — the explicit 8/4/1 fixed-width drivers
//! in `jt::simd` versus their plain scalar twins, swept over kernel ×
//! lane count × table size. This is the innermost loop of the batched
//! tier: every `*_cases` kernel in `jt::ops` walks table entries and
//! applies one of these four element-wise ops to a `lanes`-wide slice per
//! entry, so the sweep here is the per-entry shape the propagation and
//! max-product passes actually execute.
//!
//! With the on-by-default `simd` feature the `selected` column times the
//! blocked drivers; under `--no-default-features` the public names *are*
//! the scalar loops and the two columns coincide (the schema is identical
//! either way — `simd_feature` records which build produced the numbers).
//! Before timing, each point re-asserts the bit-identity contract: the
//! selected kernel and the scalar twin must agree byte for byte.
//!
//! When `FASTBN_BENCH_JSON` names a path (`make bench-json` →
//! `BENCH_kernels.json`) the sweep is also written as JSON with a stable
//! schema; the CI perf-trajectory job uploads it as an artifact on every
//! push, so kernel regressions show up as a trend across commits.
//!
//! Scale knobs: FASTBN_KERNEL_LANES (comma list, default 1,4,8,64) and
//! FASTBN_KERNEL_ENTRIES (comma list, default 1024,16384,262144).

use fastbn::bench::{print_table, Bench};
use fastbn::jt::simd;
use fastbn::rng::Rng;

fn env_list(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect::<Vec<usize>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

struct Point {
    kernel: &'static str,
    lanes: usize,
    entries: usize,
    selected_ms: f64,
    scalar_ms: f64,
    melem_per_s: f64,
}

/// One pass in the shape the `jt::ops` `*_cases` kernels use: per table
/// entry, apply the lane kernel to that entry's `lanes`-wide slice.
fn pass(kern: fn(&mut [f64], &[f64]), dst: &mut [f64], src: &[f64], lanes: usize) {
    for (d, s) in dst.chunks_exact_mut(lanes).zip(src.chunks_exact(lanes)) {
        kern(d, s);
    }
    std::hint::black_box(dst.last());
}

fn bench_point(
    kernel: &'static str,
    selected: fn(&mut [f64], &[f64]),
    plain: fn(&mut [f64], &[f64]),
    neutral_src: bool,
    lanes: usize,
    entries: usize,
    runner: &Bench,
) -> Point {
    let n = entries * lanes;
    let mut rng = Rng::new(0x5EED ^ ((lanes as u64) << 32) ^ entries as u64);
    let d0: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    // mul/div are applied in place over many timed iterations; a neutral
    // (all-ones) source keeps the destination away from overflow and
    // subnormal drift, which would distort timing. add/max tolerate a
    // random source (linear growth / saturation).
    let src: Vec<f64> = if neutral_src { vec![1.0; n] } else { (0..n).map(|_| rng.f64()).collect() };

    // bit-identity smoke before timing — the full pinning lives in the
    // jt::simd / jt::ops test suites
    let mut got = d0.clone();
    pass(selected, &mut got, &src, lanes);
    let mut want = d0.clone();
    pass(plain, &mut want, &src, lanes);
    for k in 0..n {
        assert_eq!(got[k].to_bits(), want[k].to_bits(), "{kernel} lanes {lanes} entries {entries}: drift at {k}");
    }

    let mut dst = d0.clone();
    let sel = runner.run(|| pass(selected, &mut dst, std::hint::black_box(&src), lanes));
    let mut dst = d0;
    let sca = runner.run(|| pass(plain, &mut dst, std::hint::black_box(&src), lanes));

    Point {
        kernel,
        lanes,
        entries,
        selected_ms: sel.mean_ms(),
        scalar_ms: sca.mean_ms(),
        melem_per_s: n as f64 / (sel.mean_ms() / 1e3) / 1e6,
    }
}

/// Render the perf-trajectory artifact. The schema is a contract: the CI
/// job diffs this shape against the committed `BENCH_kernels.json`, so
/// additions must keep every existing key.
fn render_json(points: &[Point]) -> String {
    let mut out = String::from("{\n  \"bench\": \"kernels\",\n  \"schema_version\": 1,\n");
    out.push_str("  \"provenance\": \"measured (cargo bench --bench kernels)\",\n");
    out.push_str(&format!("  \"lane_width\": {},\n", simd::LANE_WIDTH));
    out.push_str(&format!("  \"simd_feature\": {},\n", cfg!(feature = "simd")));
    out.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"lanes\": {}, \"entries\": {}, \"selected_ms\": {:.4}, \"scalar_ms\": {:.4}, \"speedup\": {:.3}, \"melem_per_s\": {:.1}}}{}\n",
            p.kernel,
            p.lanes,
            p.entries,
            p.selected_ms,
            p.scalar_ms,
            p.scalar_ms / p.selected_ms,
            p.melem_per_s,
            if i + 1 < points.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let lane_counts = env_list("FASTBN_KERNEL_LANES", &[1, 4, 8, 64]);
    let entry_counts = env_list("FASTBN_KERNEL_ENTRIES", &[1_024, 16_384, 262_144]);
    let runner = Bench::default();

    type Kernel = (&'static str, fn(&mut [f64], &[f64]), fn(&mut [f64], &[f64]), bool);
    let kernels: [Kernel; 4] = [
        ("add", simd::add_assign, simd::scalar::add_assign, false),
        ("mul", simd::mul_assign, simd::scalar::mul_assign, true),
        ("div", simd::div_assign, simd::scalar::div_assign, true),
        ("max", simd::max_assign, simd::scalar::max_assign, false),
    ];

    let mut points = Vec::new();
    let mut rows = Vec::new();
    for (kernel, selected, plain, neutral_src) in kernels {
        for &lanes in &lane_counts {
            for &entries in &entry_counts {
                let p = bench_point(kernel, selected, plain, neutral_src, lanes, entries, &runner);
                rows.push(vec![
                    p.kernel.to_string(),
                    format!("{}", p.lanes),
                    format!("{}", p.entries),
                    format!("{:.4}", p.selected_ms),
                    format!("{:.4}", p.scalar_ms),
                    format!("{:.3}", p.scalar_ms / p.selected_ms),
                    format!("{:.1}", p.melem_per_s),
                ]);
                points.push(p);
            }
        }
    }
    print_table(
        &format!(
            "lane kernels — selected ({}) vs scalar twins",
            if cfg!(feature = "simd") { "simd 8/4/1 blocks" } else { "scalar build" }
        ),
        &["kernel", "lanes", "entries", "selected_ms", "scalar_ms", "speedup", "Melem/s"],
        &rows,
    );

    if let Ok(path) = std::env::var("FASTBN_BENCH_JSON") {
        std::fs::write(&path, render_json(&points)).expect("write bench JSON");
        println!("\nwrote {path}");
    }
}
