//! Integration: the multi-network serving fleet end to end — registry,
//! shard router, streaming sessions, and fleet metrics.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use fastbn::bn::resolve_spec;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig, FleetServer};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::infer::query::Posteriors;
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn make_fleet(engine: EngineKind, threads: usize, shards: usize, capacity: usize) -> Arc<Fleet> {
    Arc::new(Fleet::new(FleetConfig {
        engine,
        engine_cfg: EngineConfig::default().with_threads(threads),
        shards,
        registry_capacity: capacity,
        max_exact_cost: f64::INFINITY,
    }))
}

/// Single-tree Fast-BNI-seq answers — the acceptance oracle.
fn seq_reference(jt: &Arc<JunctionTree>, cases: &[Evidence]) -> Vec<Posteriors> {
    let mut engine = EngineKind::Seq.build(Arc::clone(jt), &EngineConfig::default().with_threads(1));
    let mut state = TreeState::fresh(jt);
    cases.iter().map(|ev| engine.infer(&mut state, ev).unwrap()).collect()
}

#[test]
fn mixed_fleet_concurrent_clients_match_single_tree_seq() {
    // one fleet hosting an embedded net and a netgen paper analog, ≥ 2
    // shards each, queried concurrently from per-network client threads
    let fleet = make_fleet(EngineKind::Hybrid, 2, 2, 4);
    fleet.load("asia").unwrap();
    fleet.load("hailfinder-sim").unwrap();

    let nets = ["asia", "hailfinder-sim"];
    let mut expected = Vec::new();
    let mut case_sets = Vec::new();
    for (i, name) in nets.iter().enumerate() {
        let jt = fleet.tree(name).unwrap();
        let cases = generate(&jt.net, &CaseSpec { n_cases: 10, observed_fraction: 0.2, seed: 900 + i as u64 });
        expected.push(seq_reference(&jt, &cases));
        case_sets.push(cases);
    }

    let answers: Vec<Vec<Posteriors>> = std::thread::scope(|scope| {
        let handles: Vec<_> = nets
            .iter()
            .zip(&case_sets)
            .map(|(name, cases)| {
                let fleet = Arc::clone(&fleet);
                scope.spawn(move || {
                    cases.iter().map(|ev| fleet.query(name, ev.clone()).unwrap()).collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (n, (got, want)) in answers.iter().zip(&expected).enumerate() {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let d = g.max_abs_diff(w);
            assert!(d <= 1e-9, "{}: case {i} differs from single-tree Seq by {d:e}", nets[n]);
        }
    }

    // STATS reports per-network query counts and latency percentiles
    let stats = fleet.stats_line();
    assert!(stats.contains("| asia queries=10 errors=0"), "{stats}");
    assert!(stats.contains("| hailfinder-sim queries=10 errors=0"), "{stats}");
    assert!(stats.contains("p50_us="), "{stats}");
    assert!(stats.contains("p99_us="), "{stats}");
    for snap in fleet.metrics().snapshot() {
        assert_eq!(snap.latency.count, 10, "{}", snap.net);
        assert!(snap.latency.p50 <= snap.latency.p99, "{}", snap.net);
        assert!(snap.qps > 0.0, "{}", snap.net);
    }
}

fn tcp_session(addr: std::net::SocketAddr, requests: &[String]) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = Vec::new();
    for r in requests {
        stream.write_all(r.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        out.push(line.trim().to_string());
    }
    out
}

#[test]
fn concurrent_tcp_sessions_on_different_networks() {
    let fleet = make_fleet(EngineKind::Seq, 1, 2, 4);
    fleet.load("asia").unwrap();
    fleet.load("cancer").unwrap();
    let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    // session A streams evidence on asia, session B on cancer, concurrently
    let (asia_replies, cancer_replies) = std::thread::scope(|scope| {
        let a = scope.spawn(move || {
            let script: Vec<String> =
                ["USE asia", "OBSERVE smoke=yes", "COMMIT", "QUERY lung"].iter().map(|s| s.to_string()).collect();
            tcp_session(addr, &script)
        });
        let b = scope.spawn(move || {
            let script: Vec<String> = ["USE cancer", "OBSERVE Smoker=True", "COMMIT", "QUERY Cancer"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            tcp_session(addr, &script)
        });
        (a.join().unwrap(), b.join().unwrap())
    });

    // P(lung=yes | smoke=yes) = 0.1
    assert!(asia_replies[3].starts_with("OK yes=0.100000"), "{}", asia_replies[3]);
    // P(Cancer=True | Smoker=True) = 0.9*0.03 + 0.1*0.05 = 0.032
    assert!(cancer_replies[3].starts_with("OK True=0.032000"), "{}", cancer_replies[3]);

    // a third session scrapes fleet-wide stats
    let stats = tcp_session(addr, &["STATS".to_string()]);
    assert!(stats[0].contains("| asia queries=1"), "{}", stats[0]);
    assert!(stats[0].contains("| cancer queries=1"), "{}", stats[0]);
    server.shutdown();
}

#[test]
fn protocol_error_paths_over_tcp() {
    let fleet = make_fleet(EngineKind::Seq, 1, 2, 4);
    let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0").unwrap();
    let script: Vec<String> = [
        "LOAD no-such-net",    // unknown spec
        "USE asia",            // USE before LOAD
        "QUERY lung",          // no network selected
        "LOAD asia",
        "LOAD cancer",
        "USE asia",
        "OBSERVE Smoker=True", // cancer variable on the asia session
        "QUERY lung",          // session still healthy after the errors
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let replies = tcp_session(server.addr(), &script);
    assert!(replies[0].starts_with("ERR unknown network"), "{}", replies[0]);
    assert!(replies[1].starts_with("ERR not loaded"), "{}", replies[1]);
    assert!(replies[2].starts_with("ERR no network selected"), "{}", replies[2]);
    assert!(replies[3].starts_with("OK loaded asia"), "{}", replies[3]);
    assert!(replies[4].starts_with("OK loaded cancer"), "{}", replies[4]);
    assert!(replies[5].starts_with("OK using asia"), "{}", replies[5]);
    assert!(replies[6].starts_with("ERR unknown variable"), "{}", replies[6]);
    assert!(replies[7].starts_with("OK yes=0.055000"), "{}", replies[7]);
    server.shutdown();
}

#[test]
fn registry_eviction_keeps_the_fleet_consistent() {
    let fleet = make_fleet(EngineKind::Seq, 1, 1, 2);
    fleet.load("asia").unwrap();
    fleet.load("cancer").unwrap();
    assert!(fleet.query("asia", Evidence::none()).is_ok());
    // loading a third net evicts the LRU tree (cancer) and its shards
    fleet.load("sprinkler").unwrap();
    let names: Vec<String> = fleet.loaded().into_iter().map(|e| e.name).collect();
    assert_eq!(names, vec!["asia".to_string(), "sprinkler".to_string()]);
    assert!(fleet.query("cancer", Evidence::none()).is_err());
    assert!(fleet.query("sprinkler", Evidence::none()).is_ok());
    // an evicted net reloads (recompiles) on demand
    fleet.load("cancer").unwrap();
    assert!(fleet.query("cancer", Evidence::none()).is_ok());
}

#[test]
fn fleet_answers_match_across_engine_kinds() {
    // the fleet must be engine-agnostic: same posteriors whichever engine
    // the shards replicate
    let jt = Arc::new(JunctionTree::compile(&resolve_spec("mixed12").unwrap(), TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&jt.net, &CaseSpec { n_cases: 6, observed_fraction: 0.25, seed: 4242 });
    let want = seq_reference(&jt, &cases);
    for kind in [EngineKind::Seq, EngineKind::Hybrid, EngineKind::Element] {
        let fleet = make_fleet(kind, 2, 2, 2);
        fleet.load("mixed12").unwrap();
        for (i, (ev, w)) in cases.iter().zip(&want).enumerate() {
            let got = fleet.query("mixed12", ev.clone()).unwrap();
            let d = got.max_abs_diff(w);
            assert!(d <= 1e-9, "{kind:?} case {i}: {d:e}");
        }
    }
}

#[test]
fn batched_fleet_concurrent_clients_match_single_tree_seq() {
    // acceptance: BatchedHybridEngine posteriors match SeqEngine to ≤1e-9
    // for every case in the batch, on an embedded and a generated net,
    // under concurrent clients driving whole batches (one shard dispatch
    // per batch — the BATCH verb's API surface, at full precision)
    // 4 lanes per shard engine; batches of 10 exercise partial tails
    let fleet = Arc::new(Fleet::new(FleetConfig {
        engine: EngineKind::Batched,
        engine_cfg: EngineConfig::default().with_threads(2).with_batch(4),
        shards: 2,
        registry_capacity: 4,
        max_exact_cost: f64::INFINITY,
    }));
    fleet.load("asia").unwrap();
    fleet.load("hailfinder-sim").unwrap();

    let nets = ["asia", "hailfinder-sim"];
    let mut expected = Vec::new();
    let mut case_sets = Vec::new();
    for (i, name) in nets.iter().enumerate() {
        let jt = fleet.tree(name).unwrap();
        let cases = generate(&jt.net, &CaseSpec { n_cases: 10, observed_fraction: 0.2, seed: 1700 + i as u64 });
        expected.push(seq_reference(&jt, &cases));
        case_sets.push(cases);
    }

    let answers: Vec<Vec<Posteriors>> = std::thread::scope(|scope| {
        let handles: Vec<_> = nets
            .iter()
            .zip(&case_sets)
            .map(|(name, cases)| {
                let fleet = Arc::clone(&fleet);
                scope.spawn(move || {
                    fleet
                        .query_batch(name, cases.clone())
                        .unwrap()
                        .into_iter()
                        .map(|r| r.unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (n, (got, want)) in answers.iter().zip(&expected).enumerate() {
        assert_eq!(got.len(), want.len(), "{}", nets[n]);
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let d = g.max_abs_diff(w);
            assert!(d <= 1e-9, "{}: batched case {i} differs from single-tree Seq by {d:e}", nets[n]);
        }
    }
    // every case recorded in the per-network metrics
    let stats = fleet.stats_line();
    assert!(stats.contains("| asia queries=10 errors=0"), "{stats}");
    assert!(stats.contains("| hailfinder-sim queries=10 errors=0"), "{stats}");
}

/// Drive one BATCH collection over a live socket: returns the per-case
/// acks plus the final n result lines.
fn tcp_batch(
    addr: std::net::SocketAddr,
    net: &str,
    target: &str,
    case_lines: &[String],
) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask = |req: &str, lines: usize| -> Vec<String> {
        stream.write_all(req.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        (0..lines)
            .map(|_| {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                line.trim().to_string()
            })
            .collect()
    };
    assert!(ask(&format!("USE {net}"), 1)[0].starts_with("OK using"), "USE failed");
    let n = case_lines.len();
    assert!(ask(&format!("BATCH {n} {target}"), 1)[0].starts_with("OK batch"), "BATCH failed");
    for (i, case) in case_lines.iter().enumerate().take(n - 1) {
        let ack = ask(&format!("CASE {case}"), 1);
        assert_eq!(ack[0], format!("OK case {}/{n}", i + 1));
    }
    ask(&format!("CASE {}", case_lines[n - 1]), n)
}

#[test]
fn batch_verb_over_tcp_matches_query_replies_under_concurrent_clients() {
    let fleet = Arc::new(Fleet::new(FleetConfig {
        engine: EngineKind::Batched,
        engine_cfg: EngineConfig::default().with_threads(1).with_batch(3),
        shards: 2,
        registry_capacity: 4,
        max_exact_cost: f64::INFINITY,
    }));
    fleet.load("asia").unwrap();
    fleet.load("cancer").unwrap();
    let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    // per-net reference replies via plain QUERY (same wire formatter)
    let asia_queries: Vec<String> =
        ["QUERY lung | smoke=yes", "QUERY lung", "QUERY lung | smoke=no"].iter().map(|s| s.to_string()).collect();
    let asia_want = {
        let mut script = vec!["USE asia".to_string()];
        script.extend(asia_queries.clone());
        tcp_session(addr, &script)[1..].to_vec()
    };
    let cancer_want = {
        let script: Vec<String> =
            ["USE cancer", "QUERY Cancer | Smoker=True", "QUERY Cancer"].iter().map(|s| s.to_string()).collect();
        tcp_session(addr, &script)[1..].to_vec()
    };

    let asia_cases: Vec<String> = ["smoke=yes", "", "smoke=no"].iter().map(|s| s.to_string()).collect();
    let cancer_cases: Vec<String> = ["Smoker=True", ""].iter().map(|s| s.to_string()).collect();
    let (asia_got, cancer_got) = std::thread::scope(|scope| {
        let a = scope.spawn(|| tcp_batch(addr, "asia", "lung", &asia_cases));
        let b = scope.spawn(|| tcp_batch(addr, "cancer", "Cancer", &cancer_cases));
        (a.join().unwrap(), b.join().unwrap())
    });
    assert_eq!(asia_got, asia_want, "asia BATCH replies must match QUERY byte for byte");
    assert_eq!(cancer_got, cancer_want, "cancer BATCH replies must match QUERY byte for byte");
    server.shutdown();
}
