//! Integration: the PJRT runtime executes the AOT artifacts with exactly
//! the same numerics as the native backend and the sequential engine.
//!
//! These tests skip (with a notice) when `artifacts/` has not been built
//! or when the `xla` dependency is the offline API stub; `make test-xla`
//! builds artifacts first and runs this suite. The whole suite is compiled
//! only with the `xla` cargo feature — the default build is pure-std and
//! has no PJRT runtime to test.

#![cfg(feature = "xla")]

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastbn::bn::{embedded, netgen};
use fastbn::engine::{Engine, EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;
use fastbn::rng::Rng;
use fastbn::runtime::accel::SeqXlaEngine;
use fastbn::runtime::ops::{NativeOps, TableOps2d, XlaOps};
use fastbn::runtime::artifacts_available;

fn artifact_dir() -> Option<PathBuf> {
    let dir = fastbn::runtime::artifact_dir();
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("skipping XLA test: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Load the XLA backend, skipping (None) when it is unavailable — e.g.
/// when the `xla` dependency is the offline API stub.
fn load_ops(dir: &Path) -> Option<XlaOps> {
    match XlaOps::load(dir) {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("skipping XLA test: backend unavailable ({e})");
            None
        }
    }
}

#[test]
fn xla_backend_matches_native_across_buckets_and_ragged_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let Some(mut xla) = load_ops(&dir) else { return };
    let mut native = NativeOps;
    let mut rng = Rng::new(2024);
    let shapes = [
        (1usize, 1usize),
        (2, 7),
        (16, 16),
        (31, 63),
        (64, 64),
        (100, 200),
        (256, 256),
        (1000, 250),
    ];
    for (m, k) in shapes {
        if !xla.fits(m, k) {
            continue;
        }
        let table: Vec<f64> = (0..m * k).map(|_| rng.f64()).collect();
        let mut a = vec![0.0; m];
        let mut b = vec![0.0; m];
        native.marginalize(&table, m, k, &mut a).unwrap();
        xla.marginalize(&table, m, k, &mut b).unwrap();
        for j in 0..m {
            assert!((a[j] - b[j]).abs() < 1e-9, "marg ({m},{k}) row {j}");
        }

        let sep_new: Vec<f64> = (0..m).map(|_| rng.f64()).collect();
        // include zero rows to exercise 0/0
        let sep_old: Vec<f64> =
            (0..m).map(|_| if rng.chance(0.2) { 0.0 } else { rng.f64() + 0.05 }).collect();
        let sep_new: Vec<f64> =
            sep_new.iter().zip(&sep_old).map(|(&n, &o)| if o == 0.0 { 0.0 } else { n }).collect();
        let mut ta = table.clone();
        let mut tb = table;
        native.absorb(&mut ta, m, k, &sep_new, &sep_old).unwrap();
        xla.absorb(&mut tb, m, k, &sep_new, &sep_old).unwrap();
        for i in 0..m * k {
            assert!((ta[i] - tb[i]).abs() < 1e-9, "absorb ({m},{k}) entry {i}");
        }
    }
}

#[test]
fn seq_xla_engine_matches_pure_seq_on_asia() {
    let Some(dir) = artifact_dir() else { return };
    let net = embedded::asia();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cfg = EngineConfig::default().with_threads(1);
    // threshold 1: route EVERY message through XLA
    let mut accel = match SeqXlaEngine::new(Arc::clone(&jt), &cfg, &dir, 1) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping XLA test: backend unavailable ({e})");
            return;
        }
    };
    let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &cfg);
    let mut s1 = TreeState::fresh(&jt);
    let mut s2 = TreeState::fresh(&jt);
    let cases = generate(&net, &CaseSpec { n_cases: 8, observed_fraction: 0.25, seed: 55 });
    for (i, ev) in cases.iter().enumerate() {
        let a = accel.infer(&mut s1, ev).unwrap();
        let b = seq.infer(&mut s2, ev).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: {}", a.max_abs_diff(&b));
    }
    assert!(accel.xla_ops > 0, "XLA path never taken");
}

#[test]
fn seq_xla_engine_matches_seq_on_paper_analog() {
    let Some(dir) = artifact_dir() else { return };
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cfg = EngineConfig::default().with_threads(1);
    // realistic threshold: only big cliques go through PJRT
    let mut accel = match SeqXlaEngine::new(Arc::clone(&jt), &cfg, &dir, 512) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping XLA test: backend unavailable ({e})");
            return;
        }
    };
    let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &cfg);
    let mut s1 = TreeState::fresh(&jt);
    let mut s2 = TreeState::fresh(&jt);
    let cases = generate(&net, &CaseSpec { n_cases: 3, observed_fraction: 0.2, seed: 77 });
    for ev in &cases {
        let a = accel.infer(&mut s1, ev).unwrap();
        let b = seq.infer(&mut s2, ev).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-9);
    }
    assert!(accel.xla_ops + accel.native_ops > 0);
}

#[test]
fn batched_artifacts_match_per_table_ops() {
    let Some(dir) = artifact_dir() else { return };
    let Some(mut xla) = load_ops(&dir) else { return };
    let buckets = xla.batched_buckets();
    if buckets.is_empty() {
        eprintln!("skipping: no batched artifacts in manifest");
        return;
    }
    let mut native = NativeOps;
    let mut rng = Rng::new(88);
    for (b, m, k) in buckets {
        let tables: Vec<f64> = (0..b * m * k).map(|_| rng.f64()).collect();
        let sep_new: Vec<f64> = (0..b * m).map(|_| rng.f64()).collect();
        let sep_old: Vec<f64> = (0..b * m).map(|_| rng.f64() + 0.1).collect();

        let got = xla.marginalize_batch(&tables, b, m, k).unwrap();
        assert_eq!(got.len(), b * m);
        for i in 0..b {
            let mut want = vec![0.0; m];
            native.marginalize(&tables[i * m * k..(i + 1) * m * k], m, k, &mut want).unwrap();
            for j in 0..m {
                assert!((got[i * m + j] - want[j]).abs() < 1e-9, "bmarg case {i} row {j}");
            }
        }

        let got = xla.absorb_batch(&tables, b, m, k, &sep_new, &sep_old).unwrap();
        assert_eq!(got.len(), b * m * k);
        for i in 0..b {
            let mut want = tables[i * m * k..(i + 1) * m * k].to_vec();
            native
                .absorb(&mut want, m, k, &sep_new[i * m..(i + 1) * m], &sep_old[i * m..(i + 1) * m])
                .unwrap();
            for j in 0..m * k {
                assert!((got[i * m * k + j] - want[j]).abs() < 1e-9, "babsorb case {i} entry {j}");
            }
        }
    }
}

#[test]
fn fused_message_artifact_runs_end_to_end() {
    let Some(dir) = artifact_dir() else { return };
    // run the msg_256x256 fused artifact directly through the runtime
    let man = fastbn::runtime::buckets::Manifest::load(&dir).unwrap();
    let Some(file) = man.file_for("msg", (256, 256)) else {
        eprintln!("skipping: no fused msg artifact");
        return;
    };
    let rt = match fastbn::runtime::pjrt::PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping XLA test: backend unavailable ({e})");
            return;
        }
    };
    let exe = rt.compile_hlo_text(&dir.join(file)).unwrap();
    let mut rng = Rng::new(5);
    let child: Vec<f64> = (0..256 * 256).map(|_| rng.f64()).collect();
    let parent: Vec<f64> = (0..256 * 256).map(|_| rng.f64()).collect();
    let sep_old: Vec<f64> = (0..256).map(|_| rng.f64() + 0.1).collect();
    let outs = exe
        .run_f64_multi(&[
            (&child, &[256, 256]),
            (&parent, &[256, 256]),
            (&sep_old, &[256]),
        ])
        .unwrap();
    assert_eq!(outs.len(), 3, "expected (parent_out, sep_out, mass)");
    assert_eq!(outs[0].len(), 256 * 256);
    assert_eq!(outs[1].len(), 256);
    assert_eq!(outs[2].len(), 1);
    // verify against native composition
    let mut native = NativeOps;
    let mut msg = vec![0.0; 256];
    native.marginalize(&child, 256, 256, &mut msg).unwrap();
    let mass: f64 = msg.iter().sum();
    assert!((outs[2][0] - mass).abs() < 1e-9 * mass.max(1.0));
    let norm: Vec<f64> = msg.iter().map(|&x| x / mass).collect();
    for j in 0..256 {
        assert!((outs[1][j] - norm[j]).abs() < 1e-9);
    }
    let mut parent_native = parent;
    native.absorb(&mut parent_native, 256, 256, &norm, &sep_old).unwrap();
    for i in 0..256 * 256 {
        assert!((outs[0][i] - parent_native[i]).abs() < 1e-9, "entry {i}");
    }
}
