//! Integration: the approximate tier end to end — deterministic parallel
//! likelihood weighting, the accuracy contract against exact inference,
//! and the cost-based fallback through the fleet and cluster wire
//! protocols.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use fastbn::bn::network::Network;
use fastbn::bn::resolve_spec;
use fastbn::cluster::{ClusterConfig, ClusterHarness};
use fastbn::engine::approx::ApproxEngine;
use fastbn::engine::{Engine, EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig, FleetServer, Tier};
use fastbn::infer::query::Posteriors;
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn lw(net: &Arc<Network>, ev: &Evidence, threads: usize, samples: usize, seed: u64) -> Posteriors {
    let cfg = EngineConfig::default().with_threads(threads).with_samples(samples).with_seed(seed);
    let mut engine = ApproxEngine::from_net(Arc::clone(net), &cfg);
    engine.infer(&mut TreeState::detached(), ev).unwrap()
}

/// Exact bit pattern of every probability — `==` on f64 would also pass
/// for -0.0 vs 0.0, and the determinism contract is *byte*-identical.
fn bits(post: &Posteriors) -> Vec<Vec<u64>> {
    post.probs.iter().map(|row| row.iter().map(|p| p.to_bits()).collect()).collect()
}

#[test]
fn posteriors_are_bit_identical_across_thread_counts() {
    for spec in ["asia", "hailfinder-sim"] {
        let net = Arc::new(resolve_spec(spec).unwrap());
        let ev = match spec {
            "asia" => Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap(),
            _ => Evidence::none(),
        };
        let reference = lw(&net, &ev, 1, 50_000, 7);
        for threads in [2usize, 3, 8] {
            let got = lw(&net, &ev, threads, 50_000, 7);
            assert_eq!(bits(&reference), bits(&got), "{spec}: {threads} threads diverged from 1 thread");
            assert_eq!(reference.log_z.to_bits(), got.log_z.to_bits(), "{spec}: logZ diverged at t={threads}");
        }
        // a different seed must actually change the estimate (the seed is
        // plumbed through, not ignored)
        let reseeded = lw(&net, &ev, 2, 50_000, 8);
        assert_ne!(bits(&reference), bits(&reseeded), "{spec}: seed had no effect");
    }
}

#[test]
fn lw_matches_exact_inference_within_the_reported_half_width() {
    let net = Arc::new(resolve_spec("asia").unwrap());
    let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let mut exact_engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
    let exact = exact_engine.infer(&mut TreeState::fresh(&jt), &ev).unwrap();

    let post = lw(&net, &ev, 4, 100_000, 0x5EED);
    let info = post.approx.as_ref().expect("approximate posteriors must carry ApproxInfo");
    assert!(info.n_samples >= 100_000, "ran {} samples", info.n_samples);
    assert!(info.effective_samples > 0.0);
    for v in 0..net.n() {
        for s in 0..net.card(v) {
            let (a, e) = (post.probs[v][s], exact.probs[v][s]);
            assert!(a.is_finite() && (0.0..=1.0).contains(&a), "probs[{v}][{s}] = {a}");
            // 3× the reported 95% half-width at the exact probability —
            // far outside it the estimator (not luck) is broken
            let tol = (3.0 * info.half_width(e)).max(1e-4);
            assert!((a - e).abs() <= tol, "probs[{v}][{s}]: |{a} - {e}| > {tol}");
        }
    }
    // the spot value the fleet tests also pin: P(lung=yes | smoke=yes) = 0.1
    let lung = net.var_id("lung").unwrap();
    assert!((post.probs[lung][0] - 0.1).abs() < 5e-3 || (post.probs[lung][1] - 0.1).abs() < 5e-3);
}

#[test]
fn inconsistent_evidence_is_a_clean_error() {
    // asia's `either` is a deterministic OR of tub and lung, so this
    // combination has probability exactly zero — every sample weight is 0
    let net = Arc::new(resolve_spec("asia").unwrap());
    let ev = Evidence::from_pairs(&net, &[("tub", "no"), ("lung", "no"), ("either", "yes")]).unwrap();
    let cfg = EngineConfig::default().with_threads(2).with_samples(5_000);
    let mut engine = ApproxEngine::from_net(Arc::clone(&net), &cfg);
    let err = engine.infer(&mut TreeState::detached(), &ev).unwrap_err();
    let text = err.to_string();
    assert!(!text.contains("NaN"), "error must be a diagnosis, not a NaN artifact: {text}");
    assert!(text.contains("evidence"), "error should name the evidence as the cause: {text}");
}

fn fallback_fleet(samples: usize) -> Arc<Fleet> {
    Arc::new(Fleet::new(FleetConfig {
        engine: EngineKind::Hybrid,
        engine_cfg: EngineConfig::default().with_threads(2).with_samples(samples),
        shards: 2,
        registry_capacity: 4,
        max_exact_cost: 1e6,
    }))
}

#[test]
fn fleet_serves_an_intractable_network_from_the_approx_tier() {
    let fleet = fallback_fleet(20_000);
    let hard = fleet.load("intractable-sim").unwrap();
    assert_eq!(hard.tier, Tier::Approx);
    assert!(hard.cost.unwrap() > 1e6, "estimated cost {:?} should blow the budget", hard.cost);
    let easy = fleet.load("asia").unwrap();
    assert_eq!(easy.tier, Tier::Exact);
    assert!(easy.cost.is_none());

    // no junction tree exists for the approx resident, yet queries work
    assert!(fleet.tree("intractable-sim").is_none());
    assert!(fleet.model("intractable-sim").unwrap().is_approx());
    let post = fleet.query("intractable-sim", Evidence::none()).unwrap();
    let info = post.approx.as_ref().expect("approx tier must report its info");
    assert!(info.effective_samples > 0.0);
    for row in &post.probs {
        let sum: f64 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "unnormalized posterior row: {sum}");
    }
    // the tractable resident still answers exactly, with no approx info
    let exact = fleet.query("asia", Evidence::none()).unwrap();
    assert!(exact.approx.is_none());
}

fn tcp_session(addr: std::net::SocketAddr, requests: &[String]) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = Vec::new();
    for r in requests {
        stream.write_all(r.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        out.push(line.trim().to_string());
    }
    out
}

#[test]
fn fallback_load_and_query_round_trip_over_the_fleet_wire() {
    let fleet = fallback_fleet(20_000);
    let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0").unwrap();
    let hard = resolve_spec("intractable-sim").unwrap();
    let target = hard.vars[hard.n() - 1].name.clone();

    let script: Vec<String> = [
        "LOAD intractable-sim".to_string(),
        "LOAD asia".to_string(),
        "NETS".to_string(),
        "USE intractable-sim".to_string(),
        format!("QUERY {target}"),
        format!("QUERY {target}"),
        "USE asia".to_string(),
        "QUERY lung | smoke=yes".to_string(),
        "STATS".to_string(),
    ]
    .to_vec();
    let r = tcp_session(server.addr(), &script);

    assert!(r[0].starts_with("OK loaded intractable-sim"), "{}", r[0]);
    assert!(r[0].contains("tier=approx") && r[0].contains("cost="), "LOAD must say which tier answered: {}", r[0]);
    assert!(r[1].starts_with("OK loaded asia") && r[1].contains("tier=exact"), "{}", r[1]);
    assert!(r[2].contains("tier=approx") && r[2].contains("tier=exact"), "NETS must list both tiers: {}", r[2]);
    assert!(r[4].starts_with("OK ") && r[4].contains(" tier=approx ci95="), "{}", r[4]);
    assert!(r[4].contains(" ess="), "{}", r[4]);
    assert_eq!(r[4], r[5], "repeated approx QUERY must be byte-identical");
    // the exact tier's replies are unchanged: value pinned, no approx suffix
    assert!(r[7].starts_with("OK yes=0.100000"), "{}", r[7]);
    assert!(!r[7].contains("tier=approx"), "{}", r[7]);
    assert!(r[8].starts_with("STATS ") && r[8].contains("tier=approx") && r[8].contains("tier=exact"), "{}", r[8]);
    server.shutdown();
}

#[test]
fn cluster_front_tier_passes_the_fallback_through() {
    let backend_cfg = FleetConfig {
        engine: EngineKind::Seq,
        engine_cfg: EngineConfig::default().with_threads(1).with_samples(20_000),
        shards: 1,
        registry_capacity: 8,
        max_exact_cost: 1e6,
    };
    let cluster_cfg = ClusterConfig {
        vnodes: 64,
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_secs(10),
        probe_timeout: Duration::from_millis(500),
        probe_interval: Duration::from_millis(100),
        probe_backoff_max: Duration::from_secs(1),
        fail_threshold: 2,
        ..Default::default()
    };
    let harness = ClusterHarness::start(2, backend_cfg, cluster_cfg).unwrap();
    let hard = resolve_spec("intractable-sim").unwrap();
    let target = hard.vars[hard.n() - 1].name.clone();

    let mut client = harness.client().unwrap();
    let loaded = client.request("LOAD intractable-sim").unwrap();
    assert!(loaded.starts_with("OK loaded intractable-sim"), "{loaded}");
    assert!(loaded.contains("tier=approx"), "front tier must forward the tier: {loaded}");
    assert!(loaded.contains("backend="), "{loaded}");
    assert!(client.request("LOAD asia").unwrap().contains("tier=exact"));

    client.request("USE intractable-sim").unwrap();
    let first = client.request(&format!("QUERY {target}")).unwrap();
    assert!(first.starts_with("OK ") && first.contains(" tier=approx ci95="), "{first}");
    let second = client.request(&format!("QUERY {target}")).unwrap();
    assert_eq!(first, second, "approx answers through the router must stay deterministic");

    client.request("USE asia").unwrap();
    let exact = client.request("QUERY lung | smoke=yes").unwrap();
    assert!(exact.starts_with("OK yes=0.100000"), "{exact}");
    assert!(!exact.contains("tier=approx"), "{exact}");

    let nets = client.request("NETS").unwrap();
    assert!(nets.contains("tier=approx") && nets.contains("tier=exact"), "{nets}");
}
