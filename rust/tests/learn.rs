//! Closed-loop learning suite: the sample→learn→serve oracle, thread-count
//! determinism, and the fleet/cluster serving integration.
//!
//! The oracle (ISSUE 5 acceptance): learning from ≥50k forward samples of
//! the embedded `asia` recovers the true skeleton exactly, and posteriors
//! from the learned net's junction tree match the generating net within
//! 0.02 total variation on every single-variable query. The constants
//! (seed `0xA51A`, alpha 0.01) were validated against an offline
//! bit-exact reference implementation of the same pipeline.

use std::sync::Arc;
use std::time::Duration;

use fastbn::bn::embedded;
use fastbn::bn::network::Network;
use fastbn::cluster::harness::ClusterHarness;
use fastbn::cluster::ClusterConfig;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig, Session, SessionReply};
use fastbn::infer::query::Posteriors;
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;
use fastbn::learn::{learn, Dataset, LearnConfig, LearnReport};

/// Undirected edges of a network's true DAG, sorted.
fn true_skeleton(net: &Network) -> Vec<(usize, usize)> {
    let mut edges: Vec<(usize, usize)> =
        (0..net.n()).flat_map(|v| net.parents(v).iter().map(move |&p| (p.min(v), p.max(v)))).collect();
    edges.sort_unstable();
    edges.dedup();
    edges
}

/// Posteriors under `ev` via a single-threaded Seq engine (the oracle
/// engine the byte-level wire comparisons also use).
fn posteriors(net: &Network, ev: &Evidence) -> Posteriors {
    let jt = Arc::new(JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap());
    let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
    let mut state = TreeState::fresh(&jt);
    engine.infer(&mut state, ev).unwrap()
}

/// The `OK <state>=<prob> … logZ=…` line the servers emit for `target` —
/// reconstructed here to assert wire replies byte-for-byte against
/// in-process learning.
fn expected_reply(net: &Network, target: &str, post: &Posteriors) -> String {
    let v = net.var_id(target).unwrap();
    let entries: Vec<String> =
        net.vars[v].states.iter().zip(&post.probs[v]).map(|(s, p)| format!("{s}={p:.6}")).collect();
    format!("OK {} logZ={:.6}", entries.join(" "), post.log_z)
}

/// Everything about a report that must be invariant: structure, CPT bits,
/// and per-level accounting.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    skeleton: Vec<(usize, usize)>,
    compelled: Vec<(usize, usize)>,
    reversible: Vec<(usize, usize)>,
    parents: Vec<Vec<usize>>,
    cpt_bits: Vec<Vec<u64>>,
    levels: Vec<fastbn::learn::LevelStats>,
}

fn fingerprint(report: &LearnReport) -> Fingerprint {
    Fingerprint {
        skeleton: report.skeleton.clone(),
        compelled: report.compelled.clone(),
        reversible: report.reversible.clone(),
        parents: report.net.cpts.iter().map(|c| c.parents.clone()).collect(),
        cpt_bits: report.net.cpts.iter().map(|c| c.probs.iter().map(|p| p.to_bits()).collect()).collect(),
        levels: report.levels.clone(),
    }
}

#[test]
fn oracle_recovers_asia_exactly_and_posteriors_agree() {
    let net = embedded::asia();
    let data = Dataset::from_network(&net, 50_000, 0xA51A);
    let report = learn(&data, "asia-learned", &LearnConfig::default().with_threads(2)).unwrap();

    // exact skeleton recovery (8 edges, including both edges of the
    // deterministic `either` node — the adaptive-dof G² keeps them)
    assert_eq!(report.skeleton, true_skeleton(&net), "learned skeleton differs from asia's");

    // every single-variable posterior within 0.02 total variation
    let truth = posteriors(&net, &Evidence::none());
    let learned = posteriors(&report.net, &Evidence::none());
    for v in 0..net.n() {
        let lv = report.net.var_id(&net.vars[v].name).unwrap();
        let tv: f64 =
            0.5 * truth.probs[v].iter().zip(&learned.probs[lv]).map(|(a, b)| (a - b).abs()).sum::<f64>();
        assert!(tv <= 0.02, "P({}) drifted: TV = {tv:.5}", net.vars[v].name);
    }

    // the learned net is a first-class citizen: it compiles, serves, and
    // answers a conditional query close to the truth
    let ev_t = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
    let ev_l = Evidence::from_pairs(&report.net, &[("smoke", "yes")]).unwrap();
    let t = posteriors(&net, &ev_t);
    let l = posteriors(&report.net, &ev_l);
    let v = net.var_id("lung").unwrap();
    let lv = report.net.var_id("lung").unwrap();
    let tv: f64 = 0.5 * t.probs[v].iter().zip(&l.probs[lv]).map(|(a, b)| (a - b).abs()).sum::<f64>();
    assert!(tv <= 0.05, "P(lung | smoke=yes) drifted: TV = {tv:.5}");
}

#[test]
fn learning_is_deterministic_across_threads_and_runs() {
    let net = embedded::asia();
    let data = Dataset::from_network(&net, 20_000, 7);
    let base = learn(&data, "asia-det", &LearnConfig::default().with_threads(1)).unwrap();
    // thread count must not change skeleton, CPDAG, CPTs, or accounting
    for threads in [2usize, 8] {
        let other = learn(&data, "asia-det", &LearnConfig::default().with_threads(threads)).unwrap();
        assert_eq!(fingerprint(&other), fingerprint(&base), "threads={threads}");
    }
    // and a repeated run with the same inputs is bit-identical too
    let again = learn(&data, "asia-det", &LearnConfig::default().with_threads(8)).unwrap();
    assert_eq!(fingerprint(&again), fingerprint(&base), "repeat run");
    // regenerating the dataset from the same seed changes nothing either
    let data2 = Dataset::from_network(&net, 20_000, 7);
    assert_eq!(data2, data);
}

#[test]
fn fleet_learn_verb_matches_in_process_learning_byte_for_byte() {
    let fleet = Arc::new(Fleet::new(FleetConfig {
        engine: EngineKind::Seq,
        engine_cfg: EngineConfig::default().with_threads(1),
        shards: 1,
        registry_capacity: 4,
        max_exact_cost: f64::INFINITY,
    }));
    let mut session = Session::new(fleet);
    let line = |s: &mut Session, input: &str| match s.handle(input) {
        SessionReply::Line(l) => l,
        SessionReply::Quit => panic!("unexpected quit"),
    };
    let r = line(&mut session, "LEARN asia-l asia 5000 9");
    assert!(r.starts_with("OK learned asia-l"), "{r}");
    assert!(line(&mut session, "USE asia-l").starts_with("OK using asia-l vars=8"));
    let wire = line(&mut session, "QUERY dysp | smoke=yes");

    // the same spec learned in-process must produce the same bytes on the
    // wire: same structure, same CPT bits, same formatted posterior
    let in_process = fastbn::bn::resolve_spec("learn:asia-l:5000:9:asia").unwrap();
    let ev = Evidence::from_pairs(&in_process, &[("smoke", "yes")]).unwrap();
    let post = posteriors(&in_process, &ev);
    assert_eq!(wire, expected_reply(&in_process, "dysp", &post));
}

#[test]
fn cluster_learn_passthrough_and_deterministic_handoff() {
    let h = ClusterHarness::start(
        2,
        FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 1,
            registry_capacity: 8,
            max_exact_cost: f64::INFINITY,
        },
        ClusterConfig {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(30),
            probe_timeout: Duration::from_millis(500),
            probe_interval: Duration::from_millis(100),
            probe_backoff_max: Duration::from_secs(1),
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = h.client().unwrap();

    // LEARN through the front tier lands on the ring owner and is served
    let r = c.request("LEARN c5 cancer 5000 9").unwrap();
    assert!(r.starts_with("OK learned c5"), "{r}");
    assert!(r.contains("backend=b"), "{r}");
    let owner = h.cluster().owner("c5").expect("learned net must be in the directory");
    assert!(c.request("USE c5").unwrap().starts_with("OK using c5 vars=5"));
    let first = c.request("QUERY Xray | Smoker=True").unwrap();

    // byte-identical to the same net learned in-process
    let in_process = fastbn::bn::resolve_spec("learn:c5:5000:9:cancer").unwrap();
    let ev = Evidence::from_pairs(&in_process, &[("Smoker", "True")]).unwrap();
    assert_eq!(first, expected_reply(&in_process, "Xray", &posteriors(&in_process, &ev)));

    // the learned net shows up in the cluster-wide NETS view
    let nets = c.request("NETS").unwrap();
    assert!(nets.contains("c5[cliques="), "{nets}");

    // a LEARN with different provenance under the resident name is
    // refused by the backend, so the front must NOT overwrite the
    // directory spec — hand-offs keep re-learning the ORIGINAL net
    let r = c.request("LEARN c5 cancer 5000 10").unwrap();
    assert!(r.starts_with("ERR network \"c5\" is already resident"), "{r}");
    assert_eq!(h.cluster().spec_of("c5").as_deref(), Some("learn:c5:5000:9:cancer"));

    // hand-off: the owner leaves, the survivor RE-LEARNS from the
    // recorded learn: spec — and, because learning is deterministic,
    // serves the bit-identical network
    h.cluster().leave(&owner).unwrap();
    let survivor = h.cluster().owner("c5").expect("hand-off must re-home the learned net");
    assert_ne!(survivor, owner);
    let r = c.request("USE c5").unwrap();
    assert!(r.starts_with("OK using c5") || r.starts_with("ERR"), "{r}");
    if r.starts_with("ERR") {
        // the session's pin died with the old owner; one retry re-pins
        assert!(c.request("USE c5").unwrap().starts_with("OK using c5"), "retry USE failed");
    }
    let second = c.request("QUERY Xray | Smoker=True").unwrap();
    assert_eq!(second, first, "re-learned net on the survivor must answer byte-identically");
}

#[test]
fn csv_roundtrip_learns_the_same_network() {
    // a dataset that leaves the process as CSV and comes back learns the
    // same structure (state order is re-derived but names are stable)
    let net = embedded::cancer();
    let data = Dataset::from_network(&net, 8_000, 21);
    let direct = learn(&data, "c-direct", &LearnConfig::default().with_threads(2)).unwrap();
    let back = Dataset::from_csv(&data.to_csv()).unwrap();
    let via_csv = learn(&back, "c-csv", &LearnConfig::default().with_threads(2)).unwrap();
    assert_eq!(via_csv.skeleton, direct.skeleton);
    assert_eq!(via_csv.compelled, direct.compelled);
    // marginals agree regardless of state re-ordering
    let a = posteriors(&direct.net, &Evidence::none());
    let b = posteriors(&via_csv.net, &Evidence::none());
    for v in 0..net.n() {
        let name = &net.vars[v].name;
        let (da, db) = (direct.net.var_id(name).unwrap(), via_csv.net.var_id(name).unwrap());
        for (si, sname) in direct.net.vars[da].states.iter().enumerate() {
            let sj = via_csv.net.vars[db].state_index(sname).unwrap();
            assert!(
                (a.probs[da][si] - b.probs[db][sj]).abs() < 1e-9,
                "P({name}={sname}) differs across the CSV round trip"
            );
        }
    }
}
