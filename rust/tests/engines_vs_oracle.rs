//! Integration: every engine must reproduce the brute-force enumeration
//! oracle on random small networks, across triangulation heuristics,
//! thread counts and evidence patterns.

use std::sync::Arc;

use fastbn::bn::netgen;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::infer::exact::enumerate;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;
use fastbn::prop::{ensure, ensure_close, forall, Config};

const TOL: f64 = 1e-9;

fn check_engine_on_net(
    net: &fastbn::bn::network::Network,
    kind: EngineKind,
    cfg: &EngineConfig,
    heuristic: TriangulationHeuristic,
    n_cases: usize,
    case_seed: u64,
) -> Result<(), String> {
    let jt = Arc::new(JunctionTree::compile(net, heuristic).map_err(|e| e.to_string())?);
    jt.verify_rip().map_err(|e| e.to_string())?;
    let mut engine = kind.build(Arc::clone(&jt), cfg);
    let mut state = TreeState::fresh(&jt);
    let cases = generate(net, &CaseSpec { n_cases, observed_fraction: 0.25, seed: case_seed });
    for (i, ev) in cases.iter().enumerate() {
        let post = engine.infer(&mut state, ev).map_err(|e| format!("case {i}: {e}"))?;
        let exact = enumerate(net, ev).map_err(|e| format!("oracle case {i}: {e}"))?;
        ensure_close(post.log_z, exact.log_z, TOL, &format!("{kind} case {i} log_z"))?;
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                ensure_close(
                    post.probs[v][s],
                    exact.probs[v][s],
                    TOL,
                    &format!("{kind} case {i} P(v{v}={s})"),
                )?;
            }
        }
    }
    Ok(())
}

#[test]
fn all_engines_match_oracle_on_random_tiny_networks() {
    forall(Config::cases(12).named("engines-vs-oracle"), |rng| {
        let nodes = rng.range(3, 9);
        let net = netgen::tiny_random(rng.next_u64(), nodes);
        let cfg = EngineConfig { threads: rng.range(1, 4), min_chunk: rng.range(1, 64), ..Default::default() };
        let kind = EngineKind::ALL[rng.below(EngineKind::ALL.len())];
        check_engine_on_net(&net, kind, &cfg, TriangulationHeuristic::MinFill, 3, rng.next_u64())
    });
}

#[test]
fn every_engine_exhaustively_on_one_network() {
    let net = netgen::tiny_random(0xE2E, 8);
    for kind in EngineKind::ALL {
        let cfg = EngineConfig { threads: 4, min_chunk: 8, ..Default::default() };
        check_engine_on_net(&net, kind, &cfg, TriangulationHeuristic::MinFill, 5, 99).unwrap();
    }
}

#[test]
fn heuristics_do_not_change_results() {
    for h in [
        TriangulationHeuristic::MinFill,
        TriangulationHeuristic::MinDegree,
        TriangulationHeuristic::MinWeight,
    ] {
        let net = netgen::tiny_random(0x4E7, 7);
        let cfg = EngineConfig { threads: 2, ..Default::default() };
        check_engine_on_net(&net, EngineKind::Hybrid, &cfg, h, 4, 7).unwrap();
    }
}

#[test]
fn thread_counts_do_not_change_results() {
    let net = netgen::tiny_random(0x7777, 8);
    for threads in [1, 2, 3, 8] {
        let cfg = EngineConfig { threads, min_chunk: 2, ..Default::default() };
        for kind in EngineKind::PARALLEL {
            check_engine_on_net(&net, kind, &cfg, TriangulationHeuristic::MinFill, 3, 13).unwrap();
        }
    }
}

#[test]
fn embedded_networks_match_oracle_with_every_engine() {
    for name in fastbn::bn::embedded::NAMES {
        let net = fastbn::bn::embedded::by_name(name).unwrap();
        for kind in EngineKind::ALL {
            let cfg = EngineConfig { threads: 3, min_chunk: 4, ..Default::default() };
            check_engine_on_net(&net, kind, &cfg, TriangulationHeuristic::MinFill, 3, 0xBEEF)
                .unwrap_or_else(|e| panic!("{name}/{kind}: {e}"));
        }
    }
}

#[test]
fn posteriors_are_valid_distributions() {
    forall(Config::cases(10).named("posterior-validity"), |rng| {
        let net = netgen::tiny_random(rng.next_u64(), rng.range(4, 8));
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine =
            EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig { threads: 2, min_chunk: 4, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let cases = generate(&net, &CaseSpec { n_cases: 2, observed_fraction: 0.3, seed: rng.next_u64() });
        for ev in &cases {
            let post = engine.infer(&mut state, ev).map_err(|e| e.to_string())?;
            for v in 0..net.n() {
                let sum: f64 = post.probs[v].iter().sum();
                ensure_close(sum, 1.0, 1e-9, &format!("P(v{v}) normalization"))?;
                ensure(post.probs[v].iter().all(|&p| (0.0..=1.0 + 1e-12).contains(&p)), || {
                    format!("P(v{v}) outside [0,1]: {:?}", post.probs[v])
                })?;
            }
            // observed variables get indicator posteriors
            for &(v, s) in &ev.obs {
                ensure_close(post.probs[v][s], 1.0, 1e-9, &format!("indicator v{v}"))?;
            }
            ensure(post.log_z <= 1e-12, || format!("ln P(e) = {} must be <= 0", post.log_z))?;
        }
        Ok(())
    });
}

#[test]
fn evidence_monotonicity_log_z_decreases_with_more_evidence() {
    // P(e1, e2) <= P(e1): adding evidence can only reduce probability
    forall(Config::cases(10).named("logz-monotone"), |rng| {
        let net = netgen::tiny_random(rng.next_u64(), 7);
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let full = fastbn::bn::sample::forward_sample(&net, rng);
        // take nested prefixes of observations
        let mut obs: Vec<(usize, usize)> = Vec::new();
        let mut last_logz = 0.0f64;
        for v in 0..net.n().min(4) {
            obs.push((v, full[v]));
            let ev = fastbn::jt::evidence::Evidence::from_ids(obs.clone());
            let post = engine.infer(&mut state, &ev).map_err(|e| e.to_string())?;
            ensure(post.log_z <= last_logz + 1e-9, || {
                format!("log_z increased: {} -> {}", last_logz, post.log_z)
            })?;
            last_logz = post.log_z;
        }
        Ok(())
    });
}
