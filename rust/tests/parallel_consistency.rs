//! Integration: the four parallel engines must agree with Fast-BNI-seq on
//! *medium-sized* generated networks (too big for the enumeration oracle),
//! across thread counts, chunk sizes and root strategies, including under
//! failure injection (impossible evidence mid-batch).

use std::sync::Arc;

use fastbn::bn::netgen::NetSpec;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::evidence::Evidence;
use fastbn::jt::schedule::RootStrategy;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn medium_net(seed: u64) -> fastbn::bn::network::Network {
    NetSpec {
        name: format!("medium-{seed}"),
        nodes: 120,
        arcs: 170,
        max_parents: 3,
        card_choices: vec![(2, 0.5), (3, 0.3), (5, 0.2)],
        locality: 12,
        max_table: 1 << 13,
        alpha: 1.0,
        seed,
    }
    .generate()
}

#[test]
fn parallel_engines_agree_with_seq_on_medium_network() {
    let net = medium_net(0xAB1);
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: 6, observed_fraction: 0.2, seed: 5 });

    let seq_cfg = EngineConfig::default().with_threads(1);
    let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &seq_cfg);
    let mut seq_state = TreeState::fresh(&jt);
    let reference: Vec<_> = cases.iter().map(|ev| seq.infer(&mut seq_state, ev).unwrap()).collect();

    for kind in EngineKind::PARALLEL {
        for threads in [2, 4] {
            for min_chunk in [16, 1024] {
                let cfg = EngineConfig { threads, min_chunk, ..Default::default() };
                let mut eng = kind.build(Arc::clone(&jt), &cfg);
                let mut state = TreeState::fresh(&jt);
                for (i, ev) in cases.iter().enumerate() {
                    let post = eng.infer(&mut state, ev).unwrap();
                    let d = post.max_abs_diff(&reference[i]);
                    assert!(d < 1e-9, "{kind} t={threads} chunk={min_chunk} case {i}: diff {d}");
                }
            }
        }
    }
}

#[test]
fn root_strategy_changes_layers_not_answers() {
    let net = medium_net(0xAB2);
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: 3, observed_fraction: 0.2, seed: 6 });

    let mk = |strategy| {
        let cfg = EngineConfig { threads: 4, root_strategy: strategy, ..Default::default() };
        EngineKind::Hybrid.build(Arc::clone(&jt), &cfg)
    };
    let mut center = mk(RootStrategy::Center);
    let mut first = mk(RootStrategy::First);
    assert!(center.schedule().unwrap().height() <= first.schedule().unwrap().height());

    let mut s1 = TreeState::fresh(&jt);
    let mut s2 = TreeState::fresh(&jt);
    for ev in &cases {
        let a = center.infer(&mut s1, ev).unwrap();
        let b = first.infer(&mut s2, ev).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-9);
    }
}

#[test]
fn failure_injection_impossible_evidence_mid_batch() {
    // craft an impossible observation by forcing a deterministic CPT
    use fastbn::bn::cpt::Cpt;
    use fastbn::bn::network::Network;
    use fastbn::bn::variable::Variable;

    let vars = vec![
        Variable::new("a", &["t", "f"]),
        Variable::new("b", &["t", "f"]), // b == a deterministically
        Variable::new("c", &["t", "f"]),
    ];
    let cards = [2, 2, 2];
    let cpts = vec![
        Cpt::new(0, vec![], vec![0.5, 0.5], &cards).unwrap(),
        Cpt::new(1, vec![0], vec![1.0, 0.0, 0.0, 1.0], &cards).unwrap(),
        Cpt::new(2, vec![1], vec![0.3, 0.7, 0.6, 0.4], &cards).unwrap(),
    ];
    let net = Network::new("det", vars, cpts).unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());

    for kind in EngineKind::ALL {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig { threads: 2, min_chunk: 1, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        // good case
        let good = Evidence::from_pairs(&net, &[("a", "t"), ("b", "t")]).unwrap();
        let p1 = eng.infer(&mut state, &good).unwrap();
        // impossible case: a=t, b=f
        let bad = Evidence::from_pairs(&net, &[("a", "t"), ("b", "f")]).unwrap();
        assert!(eng.infer(&mut state, &bad).is_err(), "{kind} must reject impossible evidence");
        // engine must fully recover afterwards
        let p2 = eng.infer(&mut state, &good).unwrap();
        assert!(p1.max_abs_diff(&p2) < 1e-12, "{kind} state corrupted after failure");
    }
}

#[test]
fn repeated_inference_is_deterministic_per_engine() {
    let net = medium_net(0xAB3);
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let ev = generate(&net, &CaseSpec { n_cases: 1, observed_fraction: 0.2, seed: 9 }).remove(0);
    // sequential engines must be bitwise deterministic
    for kind in [EngineKind::Unb, EngineKind::Seq] {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let a = eng.infer(&mut state, &ev).unwrap();
        let b = eng.infer(&mut state, &ev).unwrap();
        assert_eq!(a.log_z.to_bits(), b.log_z.to_bits(), "{kind}");
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                assert_eq!(a.probs[v][s].to_bits(), b.probs[v][s].to_bits(), "{kind} v{v}s{s}");
            }
        }
    }
    // parallel engines: agreement within fp-reduction tolerance
    for kind in EngineKind::PARALLEL {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig { threads: 4, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let a = eng.infer(&mut state, &ev).unwrap();
        let b = eng.infer(&mut state, &ev).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-10, "{kind}");
    }
}

#[test]
fn engines_agree_with_likelihood_weighting_on_a_paper_analog() {
    // statistical cross-check on a network too large for enumeration
    let net = fastbn::bn::netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let mut engine =
        EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig { threads: 2, ..Default::default() });
    let mut state = TreeState::fresh(&jt);
    let cases = generate(&net, &CaseSpec { n_cases: 2, observed_fraction: 0.15, seed: 404 });
    for (i, ev) in cases.iter().enumerate() {
        let post = engine.infer(&mut state, ev).unwrap();
        let lw = fastbn::infer::approx::likelihood_weighting(&net, ev, 150_000, 505 + i as u64).unwrap();
        if lw.effective_samples < 1_000.0 {
            continue; // too-degenerate case for a statistical check
        }
        let tol = 6.0 / lw.effective_samples.sqrt() + 0.01;
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                let d = (post.probs[v][s] - lw.probs[v][s]).abs();
                assert!(d < tol, "case {i} v{v}s{s}: JT {} vs LW {} (tol {tol})", post.probs[v][s], lw.probs[v][s]);
            }
        }
    }
}

#[test]
fn soft_evidence_consistent_across_engines() {
    let net = medium_net(0xAB4);
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let weights = |v: usize, hot: f64| -> Vec<f64> {
        (0..net.card(v)).map(|s| if s == 0 { hot } else { 1.0 }).collect()
    };
    let ev = Evidence::from_ids(vec![(3, 0)])
        .with_soft(10, weights(10, 2.0))
        .unwrap()
        .with_soft(20, weights(20, 0.5))
        .unwrap();
    let mut reference: Option<fastbn::infer::query::Posteriors> = None;
    for kind in EngineKind::ALL {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig { threads: 2, min_chunk: 64, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let post = eng.infer(&mut state, &ev).unwrap();
        if let Some(r) = &reference {
            assert!(post.max_abs_diff(r) < 1e-9, "{kind}");
        } else {
            reference = Some(post);
        }
    }
}

#[test]
fn single_clique_and_chain_topologies() {
    use fastbn::bn::cpt::Cpt;
    use fastbn::bn::network::Network;
    use fastbn::bn::variable::Variable;

    // fully-connected triple -> single clique, no messages at all
    let vars = vec![
        Variable::with_card("x", 2),
        Variable::with_card("y", 2),
        Variable::with_card("z", 2),
    ];
    let cards = [2, 2, 2];
    let cpts = vec![
        Cpt::new(0, vec![], vec![0.3, 0.7], &cards).unwrap(),
        Cpt::new(1, vec![0], vec![0.2, 0.8, 0.9, 0.1], &cards).unwrap(),
        Cpt::new(2, vec![0, 1], vec![0.1, 0.9, 0.4, 0.6, 0.8, 0.2, 0.5, 0.5], &cards).unwrap(),
    ];
    let net = Network::new("tri", vars, cpts).unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    assert_eq!(jt.n_cliques(), 1);
    let exact = fastbn::infer::exact::enumerate(&net, &Evidence::none()).unwrap();
    for kind in EngineKind::ALL {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig { threads: 2, min_chunk: 1, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let post = eng.infer(&mut state, &Evidence::none()).unwrap();
        for v in 0..3 {
            assert!((post.probs[v][0] - exact.probs[v][0]).abs() < 1e-12, "{kind}");
        }
    }

    // long chain -> many layers, each with a single tiny message
    let n = 40;
    let vars: Vec<Variable> = (0..n).map(|i| Variable::with_card(format!("c{i}"), 2)).collect();
    let cards2 = vec![2usize; n];
    let mut cpts = vec![Cpt::new(0, vec![], vec![0.6, 0.4], &cards2).unwrap()];
    for i in 1..n {
        cpts.push(Cpt::new(i, vec![i - 1], vec![0.7, 0.3, 0.2, 0.8], &cards2).unwrap());
    }
    let chain = Network::new("chain", vars, cpts).unwrap();
    let jt = Arc::new(JunctionTree::compile(&chain, TriangulationHeuristic::MinFill).unwrap());
    let ev = Evidence::from_ids(vec![(0, 0), (n - 1, 1)]);
    let mut reference = None;
    for kind in EngineKind::ALL {
        let mut eng = kind.build(Arc::clone(&jt), &EngineConfig { threads: 3, min_chunk: 1, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let post = eng.infer(&mut state, &ev).unwrap();
        if let Some(r) = &reference {
            let d = post.max_abs_diff(r);
            assert!(d < 1e-9, "{kind}: {d}");
        } else {
            reference = Some(post);
        }
    }
}

/// Regression (ISSUE 4 satellite): a tree with **zero separators** —
/// single-clique and fully-disconnected networks — must be a working path
/// through every engine, batched included. `Scratch::for_tree` sizes its
/// buffers from `max sep len` (now 0 for such trees); no message is ever
/// sent, so collect/distribute reduce to root normalization only.
#[test]
fn zero_separator_trees_work_through_every_engine() {
    use fastbn::bn::cpt::Cpt;
    use fastbn::bn::network::Network;
    use fastbn::bn::variable::Variable;
    use fastbn::engine::batched::BatchedHybridEngine;

    // one-variable net: 1 clique, 0 separators
    let single = Network::new(
        "single",
        vec![Variable::with_card("a", 3)],
        vec![Cpt::new(0, vec![], vec![0.2, 0.3, 0.5], &[3]).unwrap()],
    )
    .unwrap();
    // two isolated variables: a 2-clique forest, still 0 separators
    let forest = Network::new(
        "forest",
        vec![Variable::with_card("a", 2), Variable::with_card("b", 3)],
        vec![
            Cpt::new(0, vec![], vec![0.4, 0.6], &[2, 3]).unwrap(),
            Cpt::new(1, vec![], vec![0.2, 0.3, 0.5], &[2, 3]).unwrap(),
        ],
    )
    .unwrap();

    for net in [&single, &forest] {
        let jt = Arc::new(JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap());
        assert_eq!(jt.seps.len(), 0, "{}", net.name);
        let exact = fastbn::infer::exact::enumerate(net, &Evidence::none()).unwrap();
        let ev_a = Evidence::from_ids(vec![(0, 1)]);
        let exact_a = fastbn::infer::exact::enumerate(net, &ev_a).unwrap();
        for kind in EngineKind::ALL {
            let cfg = EngineConfig { threads: 2, min_chunk: 1, ..Default::default() };
            let mut eng = kind.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let prior = eng.infer(&mut state, &Evidence::none()).unwrap();
            assert!(prior.max_abs_diff(&exact) < 1e-9, "{kind} {} prior", net.name);
            let cond = eng.infer(&mut state, &ev_a).unwrap();
            assert!(cond.max_abs_diff(&exact_a) < 1e-9, "{kind} {} evidence", net.name);
        }
        // the batched engine, with a multi-lane batch mixing the cases
        let cfg = EngineConfig { threads: 2, min_chunk: 1, ..Default::default() }.with_batch(3);
        let mut batched = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
        let outs = batched.infer_cases(&[Evidence::none(), ev_a.clone(), Evidence::none()]);
        assert!(outs[0].as_ref().unwrap().max_abs_diff(&exact) < 1e-9, "{} batched prior", net.name);
        assert!(outs[1].as_ref().unwrap().max_abs_diff(&exact_a) < 1e-9, "{} batched evidence", net.name);
        assert!(outs[2].as_ref().unwrap().max_abs_diff(&exact) < 1e-9, "{} batched tail lane", net.name);
    }
}
