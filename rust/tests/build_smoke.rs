//! Build smoke test: the first thing a fresh checkout should pass.
//!
//! Compiles the embedded `asia` network, runs every default-feature
//! [`EngineKind`] on the same query, and asserts the posteriors agree to
//! 1e-9 — a minimal end-to-end proof that the crate builds into a working
//! inference system before the heavier integration suites run.

use std::sync::Arc;

use fastbn::bn::embedded;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

#[test]
fn asia_compiles_and_all_engines_agree() {
    let net = embedded::asia();
    assert_eq!(net.n(), 8, "embedded asia must parse to 8 variables");
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    jt.verify_rip().unwrap();

    let ev = Evidence::from_pairs(&net, &[("smoke", "yes"), ("dysp", "yes")]).unwrap();
    let cfg = EngineConfig { threads: 2, min_chunk: 4, ..Default::default() };

    let mut reference = None;
    for kind in EngineKind::ALL {
        let mut engine = kind.build(Arc::clone(&jt), &cfg);
        let mut state = TreeState::fresh(&jt);
        let post = engine.infer(&mut state, &ev).unwrap();

        // posteriors are distributions and the evidence mass is sensible
        for v in 0..net.n() {
            let sum: f64 = post.probs[v].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{kind}: P(v{v}) sums to {sum}");
        }
        assert!(post.log_z < 0.0, "{kind}: ln P(e) = {} must be negative", post.log_z);

        match &reference {
            None => reference = Some(post),
            Some(r) => {
                let d = post.max_abs_diff(r);
                assert!(d < 1e-9, "{kind} disagrees with {}: max |Δ| = {d}", EngineKind::ALL[0]);
            }
        }
    }

    // anchor one hand-derived value: P(lung = yes | smoke = yes, dysp) > P(lung | smoke)
    let r = reference.unwrap();
    let lung = net.var_id("lung").unwrap();
    assert!(r.probs[lung][0] > 0.1, "dyspnoea should raise P(lung | smoke) above the prior 0.1");
}
