//! Integration: structural invariants of the junction-tree compiler and
//! the traversal schedules on randomly generated networks.

use std::sync::Arc;

use fastbn::bn::netgen::{self, NetSpec};
use fastbn::jt::schedule::{RootStrategy, Schedule};
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::{is_subset, TriangulationHeuristic};
use fastbn::prop::{ensure, forall, Config};

fn random_spec(rng: &mut fastbn::rng::Rng) -> NetSpec {
    let nodes = rng.range(2, 40);
    NetSpec {
        name: "inv".into(),
        nodes,
        arcs: rng.range(nodes / 2, nodes * 2),
        max_parents: rng.range(1, 4),
        card_choices: vec![(2, 0.5), (3, 0.3), (4, 0.2)],
        locality: rng.range(2, nodes.max(3)),
        max_table: 1 << 12,
        alpha: 1.0,
        seed: rng.next_u64(),
    }
}

#[test]
fn rip_and_family_coverage_hold() {
    forall(Config::cases(30).named("rip"), |rng| {
        let net = random_spec(rng).generate();
        let h = [
            TriangulationHeuristic::MinFill,
            TriangulationHeuristic::MinDegree,
            TriangulationHeuristic::MinWeight,
        ][rng.below(3)];
        let jt = JunctionTree::compile(&net, h).map_err(|e| e.to_string())?;
        jt.verify_rip().map_err(|e| e.to_string())?;
        // every family inside its assigned clique
        for v in 0..net.n() {
            let mut fam: Vec<usize> = net.parents(v).to_vec();
            fam.push(v);
            fam.sort_unstable();
            ensure(is_subset(&fam, &jt.cliques[jt.cpt_home[v]].vars), || {
                format!("family of {v} not inside clique {}", jt.cpt_home[v])
            })?;
        }
        // tree structure: #seps = #cliques - #components
        let comps = {
            let mut seen = vec![false; jt.n_cliques()];
            let mut n = 0usize;
            for start in 0..jt.n_cliques() {
                if seen[start] {
                    continue;
                }
                n += 1;
                let mut stack = vec![start];
                seen[start] = true;
                while let Some(c) = stack.pop() {
                    for &(nb, _) in &jt.adj[c] {
                        if !seen[nb] {
                            seen[nb] = true;
                            stack.push(nb);
                        }
                    }
                }
            }
            n
        };
        ensure(jt.seps.len() == jt.n_cliques() - comps, || {
            format!("{} seps for {} cliques / {comps} components", jt.seps.len(), jt.n_cliques())
        })
    });
}

#[test]
fn schedules_are_valid_layerings() {
    forall(Config::cases(30).named("schedule"), |rng| {
        let net = random_spec(rng).generate();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).map_err(|e| e.to_string())?;
        let strat = [RootStrategy::Center, RootStrategy::First][rng.below(2)];
        let s = Schedule::build(&jt, strat);
        // every clique has a depth; parents are one level up
        for c in 0..jt.n_cliques() {
            match s.parent[c] {
                None => ensure(s.depth[c] == 0, || format!("root {c} at depth {}", s.depth[c]))?,
                Some((p, _)) => ensure(s.depth[c] == s.depth[p] + 1, || "bad depth".into())?,
            }
        }
        // message count = #separators per phase
        ensure(s.n_messages() == jt.seps.len(), || "missing messages".into())?;
        let down_count: usize = s.down_layers.iter().map(|l| l.len()).sum();
        ensure(down_count == jt.seps.len(), || "missing down messages".into())?;
        // collect dependencies: children before parents
        let mut sent = vec![false; jt.n_cliques()];
        for layer in &s.up_layers {
            for m in layer {
                for &(ch, _) in &s.children[m.from] {
                    ensure(sent[ch], || format!("{} sent before child {ch}", m.from))?;
                }
            }
            for m in layer {
                sent[m.from] = true;
            }
        }
        Ok(())
    });
}

#[test]
fn center_root_never_taller_than_first() {
    forall(Config::cases(25).named("center-root"), |rng| {
        let net = random_spec(rng).generate();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).map_err(|e| e.to_string())?;
        let center = Schedule::build(&jt, RootStrategy::Center);
        let first = Schedule::build(&jt, RootStrategy::First);
        ensure(center.height() <= first.height(), || {
            format!("center {} > first {}", center.height(), first.height())
        })
    });
}

#[test]
fn paper_suite_compiles_with_sane_shapes() {
    for spec in netgen::paper_suite() {
        let net = spec.generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        jt.verify_rip().unwrap();
        let stats = jt.stats();
        assert!(stats.cliques > 10, "{}: only {} cliques", spec.name, stats.cliques);
        assert!(
            stats.total_clique_entries < 200_000_000,
            "{}: {} entries won't fit the benchmark budget",
            spec.name,
            stats.total_clique_entries
        );
        let sched = Schedule::build(&jt, RootStrategy::Center);
        assert!(sched.height() >= 2, "{}: degenerate tree", spec.name);
    }
}

#[test]
fn bif_roundtrip_preserves_random_networks() {
    forall(Config::cases(20).named("bif-roundtrip"), |rng| {
        let net = random_spec(rng).generate();
        let text = fastbn::bn::bif::write(&net);
        let back = fastbn::bn::bif::parse(&text).map_err(|e| e.to_string())?;
        ensure(back.n() == net.n(), || "node count changed".into())?;
        for v in 0..net.n() {
            ensure(back.vars[v] == net.vars[v], || format!("variable {v} changed"))?;
            ensure(back.cpts[v].parents == net.cpts[v].parents, || format!("parents of {v} changed"))?;
            for (a, b) in net.cpts[v].probs.iter().zip(&back.cpts[v].probs) {
                if (a - b).abs() > 1e-12 {
                    return Err(format!("CPT of {v} changed: {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn run_maps_agree_with_entry_maps_on_compiled_trees() {
    forall(Config::cases(15).named("run-vs-entry-maps"), |rng| {
        let net = random_spec(rng).generate();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).map_err(|e| e.to_string())?;
        for (sid, sep) in jt.seps.iter().enumerate() {
            for &cid in &[sep.a, sep.b] {
                let em = &jt.edge_maps[sid];
                let entry = em.from(sep, cid);
                let runs = em.runs_from(sep, cid);
                ensure(runs.map.len() * runs.run_len == entry.len(), || {
                    format!("sep {sid}: run map size mismatch")
                })?;
                for (i, &e) in entry.iter().enumerate() {
                    if runs.map[i / runs.run_len] != e {
                        return Err(format!("sep {sid} clique {cid} entry {i} disagrees"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn index_maps_project_consistently_with_potential_marginalization() {
    // pushing a clique table through the cached edge map must equal the
    // Potential::marginalize_onto result
    forall(Config::cases(15).named("map-vs-potential"), |rng| {
        let net = random_spec(rng).generate();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).map_err(|e| e.to_string())?;
        if jt.seps.is_empty() {
            return Ok(());
        }
        let sid = rng.below(jt.seps.len());
        let sep = &jt.seps[sid];
        let c = &jt.cliques[sep.a];
        // random table over clique a
        let data: Vec<f64> = (0..c.len).map(|_| rng.f64()).collect();
        let pot = fastbn::jt::potential::Potential {
            vars: c.vars.clone(),
            cards: c.cards.clone(),
            data: data.clone(),
        };
        let expect = pot.marginalize_onto(&sep.vars);
        let mut got = vec![0.0; sep.len];
        fastbn::jt::ops::marg_with_map(&data, &jt.edge_maps[sid].from_a, &mut got);
        for j in 0..sep.len {
            if (got[j] - expect.data[j]).abs() > 1e-9 {
                return Err(format!("entry {j}: {} vs {}", got[j], expect.data[j]));
            }
        }
        Ok(())
    });
}

/// ISSUE 4 satellite: the arena layout must round-trip the old
/// per-table construction. For random nets: layout ranges tile the arena
/// exactly (cliques then seps, disjoint, total covered); every clique
/// slice of the prototype arena equals an independently rebuilt CPT
/// product; every separator slice is all-ones; and a multi-lane
/// `BatchState` reset leaves no stale lane behind.
#[test]
fn arena_layout_roundtrips_per_table_construction() {
    use fastbn::jt::mapping::build_map;
    use fastbn::jt::potential::Potential;
    use fastbn::jt::state::{BatchState, TreeState};

    forall(Config::cases(25).named("arena"), |rng| {
        let net = random_spec(rng).generate();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).map_err(|e| e.to_string())?;

        // ranges tile 0..total in order: cliques first, then separators
        let l = &jt.layout;
        let mut cursor = 0usize;
        for c in 0..jt.n_cliques() {
            let r = l.clique_range(c);
            ensure(r.start == cursor, || format!("clique {c} starts at {} not {cursor}", r.start))?;
            ensure(r.len() == jt.cliques[c].len, || format!("clique {c} length mismatch"))?;
            cursor = r.end;
        }
        for s in 0..jt.seps.len() {
            let r = l.sep_range(s);
            ensure(r.start == cursor, || format!("sep {s} starts at {} not {cursor}", r.start))?;
            ensure(r.len() == jt.seps[s].len, || format!("sep {s} length mismatch"))?;
            cursor = r.end;
        }
        ensure(cursor == l.total, || format!("arena total {} != end {cursor}", l.total))?;
        ensure(jt.arena_proto.len() == l.total, || "prototype arena length mismatch".to_string())?;

        // rebuild each clique's prototype the old per-table way: the
        // product of the CPTs homed on it, expanded through build_map
        let mut rebuilt: Vec<Vec<f64>> = jt.cliques.iter().map(|c| vec![1.0; c.len]).collect();
        for v in 0..net.n() {
            let home = jt.cpt_home[v];
            let pot = Potential::from_cpt(&net, v);
            let c = &jt.cliques[home];
            let map = build_map(&c.vars, &c.cards, &pot.vars, &pot.cards);
            for (i, x) in rebuilt[home].iter_mut().enumerate() {
                *x *= pot.data[map[i] as usize];
            }
        }
        for c in 0..jt.n_cliques() {
            let arena_slice = jt.proto_clique(c);
            for (i, (&a, &b)) in arena_slice.iter().zip(&rebuilt[c]).enumerate() {
                ensure((a - b).abs() < 1e-12, || format!("clique {c} entry {i}: arena {a} vs rebuilt {b}"))?;
            }
        }
        for s in 0..jt.seps.len() {
            ensure(jt.arena_proto[l.sep_range(s)].iter().all(|&x| x == 1.0), || {
                format!("sep {s} prototype is not all-ones")
            })?;
        }

        // single-case state: fresh == proto, reset clears a scribble
        let mut st = TreeState::fresh(&jt);
        ensure(st.data() == &jt.arena_proto[..], || "fresh state != prototype arena".to_string())?;
        for x in st.data_mut() {
            *x = -1.0;
        }
        st.reset(&jt);
        ensure(st.data() == &jt.arena_proto[..], || "reset did not restore the prototype".to_string())?;

        // batch state: scribble one lane, reset, verify no stale lane
        let lanes = 1 + (rng.below(4));
        let mut bs = BatchState::fresh(&jt, lanes);
        let dirty = rng.below(lanes);
        let n_lanes = bs.lanes();
        for chunk in bs.data_mut().chunks_mut(n_lanes) {
            chunk[dirty] = f64::NAN;
        }
        bs.reset();
        for lane in 0..n_lanes {
            for c in 0..jt.n_cliques() {
                let got = bs.lane_of_clique(c, lane);
                ensure(got == jt.proto_clique(c), || {
                    format!("lane {lane} clique {c} stale after reset")
                })?;
            }
        }
        Ok(())
    });
}
