//! Integration: the cross-process cluster tier — consistency against a
//! single-process fleet (including across a membership change) and
//! fault injection (backends killed mid-session and mid-batch, the
//! primary front router killed under a live session).
//!
//! Everything runs through [`ClusterHarness`]: real TCP between front
//! tier and backends, ephemeral ports, bounded timeouts everywhere, so a
//! routing bug fails an assertion instead of hanging the suite.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastbn::bn::{bif, netgen};
use fastbn::cluster::harness::query_line;
use fastbn::cluster::{ClusterClient, ClusterConfig, ClusterHarness};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig, FleetServer};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::evidence::Evidence;

fn backend_cfg() -> FleetConfig {
    FleetConfig {
        engine: EngineKind::Seq,
        engine_cfg: EngineConfig::default().with_threads(1),
        shards: 2,
        registry_capacity: 8,
        max_exact_cost: f64::INFINITY,
    }
}

/// Short probe/backoff intervals so failure detection fits test budgets;
/// every timeout stays finite so nothing can hang the suite.
fn fast_cluster_cfg() -> ClusterConfig {
    ClusterConfig {
        vnodes: 64,
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_secs(5),
        probe_timeout: Duration::from_millis(500),
        probe_interval: Duration::from_millis(100),
        probe_backoff_max: Duration::from_secs(1),
        fail_threshold: 2,
        ..Default::default()
    }
}

/// Write a small synthetic network to a temp `.bif` so the cluster hosts
/// a *generated* net alongside the embedded ones. The name `gen2` is
/// load-bearing: under the deterministic ring (64 vnodes per member, ids
/// `b0`/`b1`/`b2`) it is owned by `b1` at two backends and hands off to
/// `b2` when the third joins — the movement the join test asserts.
fn write_gen_net(name: &str) -> std::path::PathBuf {
    let spec = netgen::NetSpec {
        name: name.to_string(),
        nodes: 12,
        arcs: 18,
        max_parents: 3,
        card_choices: vec![(2, 0.6), (3, 0.4)],
        locality: 6,
        max_table: 1 << 10,
        alpha: 1.0,
        seed: 77,
    };
    let path = std::env::temp_dir().join(format!("fastbn-cluster-{}-{name}.bif", std::process::id()));
    std::fs::write(&path, bif::write(&spec.generate())).unwrap();
    path
}

/// Both consistency layers at once.
///
/// Full precision: a cluster answer is computed by the owning backend's
/// in-process fleet, so compare its `Posteriors` against the
/// single-process reference fleet at ≤ 1e-9. Wire: concurrent per-net
/// clients through the front tier must reproduce the single-process
/// `FleetServer`'s reply lines byte for byte (same engine, same
/// deterministic propagation, same formatter).
fn check_consistency(harness: &ClusterHarness, reference: &Arc<Fleet>, names: &[&str], cases: &[Vec<Evidence>]) {
    for (name, case_set) in names.iter().zip(cases) {
        let owner = harness.cluster().owner(name).unwrap_or_else(|| panic!("{name} has no owner"));
        let backend = harness.backend_fleet(&owner).unwrap_or_else(|| panic!("{owner} is not running"));
        for (i, ev) in case_set.iter().enumerate() {
            let got = backend.query(name, ev.clone()).unwrap();
            let want = reference.query(name, ev.clone()).unwrap();
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-9, "{name} case {i}: cluster differs from single-process fleet by {d:e}");
        }
    }

    let ref_server = FleetServer::start(Arc::clone(reference), "127.0.0.1:0").unwrap();
    let mut expected: Vec<Vec<String>> = Vec::new();
    for (name, case_set) in names.iter().zip(cases) {
        let jt = reference.tree(name).unwrap();
        let target = jt.net.vars[jt.net.n() - 1].name.clone();
        let mut client = ClusterClient::connect(ref_server.addr()).unwrap();
        assert!(client.request(&format!("USE {name}")).unwrap().starts_with("OK using"));
        expected.push(case_set.iter().map(|ev| client.request(&query_line(&jt.net, &target, ev)).unwrap()).collect());
    }
    let got: Vec<Vec<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = names
            .iter()
            .zip(cases)
            .map(|(name, case_set)| {
                let front = harness.front_addr();
                let jt = reference.tree(name).unwrap();
                scope.spawn(move || {
                    let mut client = ClusterClient::connect(front).unwrap();
                    let r = client.request(&format!("USE {name}")).unwrap();
                    assert!(r.starts_with("OK using"), "{r}");
                    let target = jt.net.vars[jt.net.n() - 1].name.clone();
                    case_set
                        .iter()
                        .map(|ev| client.request(&query_line(&jt.net, &target, ev)).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for ((name, g), w) in names.iter().zip(&got).zip(&expected) {
        assert_eq!(g, w, "{name}: front-tier wire replies diverged from the single-process server");
    }
    ref_server.shutdown();
}

#[test]
fn cluster_matches_single_process_fleet_across_a_join() {
    let gen_path = write_gen_net("gen2");
    let specs: Vec<String> =
        vec!["asia".into(), "cancer".into(), "mixed12".into(), gen_path.to_str().unwrap().into()];
    let names = ["asia", "cancer", "mixed12", "gen2"];

    let reference = Arc::new(Fleet::new(backend_cfg()));
    for spec in &specs {
        reference.load(spec).unwrap();
    }

    let mut harness = ClusterHarness::start(2, backend_cfg(), fast_cluster_cfg()).unwrap();
    {
        let mut c = harness.client().unwrap();
        for spec in &specs {
            let r = c.request(&format!("LOAD {spec}")).unwrap();
            assert!(r.starts_with("OK loaded"), "{r}");
        }
        let stats = c.request("STATS").unwrap();
        assert!(stats.contains("backends=2 alive=2 nets=4"), "{stats}");
    }

    let mut cases: Vec<Vec<Evidence>> = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let jt = reference.tree(name).unwrap();
        cases.push(generate(&jt.net, &CaseSpec { n_cases: 6, observed_fraction: 0.25, seed: 1000 + i as u64 }));
    }

    check_consistency(&harness, &reference, &names, &cases);

    // two sessions straddle the membership change. `clean` has no staged
    // or committed evidence, so the front is free to reroute it
    // invisibly — its answers must stay byte-identical across the join.
    // `pinned` has *committed* evidence living in its backend session, so
    // it must get a clean "moved" error, never silently-rerouted answers
    // carrying another backend session's state.
    let gjt = reference.tree("gen2").unwrap();
    let (gv, gs) = (gjt.net.vars[0].name.clone(), gjt.net.vars[0].states[0].clone());
    let mut clean = harness.client().unwrap();
    assert!(clean.request("USE gen2").unwrap().starts_with("OK using gen2"));
    let clean_want = clean.request("QUERY x0").unwrap();
    assert!(clean_want.starts_with("OK "), "{clean_want}");
    let mut pinned = harness.client().unwrap();
    assert!(pinned.request("USE gen2").unwrap().starts_with("OK using gen2"));
    assert!(pinned.request(&format!("OBSERVE {gv}={gs}")).unwrap().starts_with("OK staged 1"));
    assert!(pinned.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));

    let owners_before: Vec<Option<String>> = names.iter().map(|n| harness.cluster().owner(n)).collect();
    assert_eq!(harness.add_backend().unwrap(), "b2");

    let mut moved = Vec::new();
    for (name, before) in names.iter().zip(&owners_before) {
        let after = harness.cluster().owner(name);
        assert!(after.is_some(), "{name} lost its owner across the join");
        if &after != before {
            // minimal movement: a join moves ownership only *to* the joiner
            assert_eq!(after.as_deref(), Some("b2"), "{name} moved between survivors");
            // and the hand-off ran: resident on the new owner, evicted
            // from the old one
            assert!(harness.backend_fleet("b2").unwrap().tree(name).is_some(), "{name} not resident on b2");
            let old = harness.backend_fleet(before.as_deref().unwrap()).unwrap();
            assert!(old.tree(name).is_none(), "{name} still resident on {before:?} after hand-off");
            moved.push(*name);
        }
    }
    // deterministic ring: gen2 is the known mover at this topology
    assert!(moved.contains(&"gen2"), "join rebalanced nothing (owners before: {owners_before:?})");

    let r = pinned.request("QUERY x0").unwrap();
    assert!(r.starts_with("ERR network \"gen2\" moved"), "{r}");
    assert!(pinned.request("USE gen2").unwrap().starts_with("OK using gen2"));

    // the clean session crossed the same join without a single error
    // line: the front re-derived the new owner from the ring and the
    // reply is byte-identical to the pre-join one
    assert_eq!(clean.request("QUERY x0").unwrap(), clean_want, "clean session answer changed across the join");

    check_consistency(&harness, &reference, &names, &cases);
    drop(harness);
    let _ = std::fs::remove_file(gen_path);
}

#[test]
fn killed_backend_reroutes_and_sessions_get_clean_errors() {
    let mut harness = ClusterHarness::start(2, backend_cfg(), fast_cluster_cfg()).unwrap();
    let mut c = harness.client().unwrap();
    assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    assert!(c.request("LOAD cancer").unwrap().starts_with("OK loaded cancer"));

    let victim = harness.cluster().owner("asia").unwrap();
    let survivor = harness.live_backend_ids().into_iter().find(|id| *id != victim).unwrap();

    // a streaming session pinned to the doomed backend
    assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
    assert!(c.request("OBSERVE smoke=yes").unwrap().starts_with("OK staged 1"));
    assert!(c.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));
    assert!(c.request("QUERY lung").unwrap().starts_with("OK yes=0.100000"));

    assert!(harness.kill_backend(&victim));

    // the very next verb: a clean, *bounded* protocol error — whichever
    // race wins (session trips on the dead conn, or the prober already
    // declared death and the pin reads as moved)
    let t0 = Instant::now();
    let r = c.request("QUERY lung").unwrap();
    assert!(r.starts_with("ERR"), "{r}");
    assert!(r.contains("unreachable") || r.contains("moved"), "{r}");
    assert!(t0.elapsed() < Duration::from_secs(10), "error reply took {:?}", t0.elapsed());

    // failover re-homes asia onto the survivor
    let deadline = Instant::now() + Duration::from_secs(10);
    while harness.cluster().owner("asia").as_deref() != Some(survivor.as_str()) {
        assert!(Instant::now() < deadline, "asia never rerouted; owner={:?}", harness.cluster().owner("asia"));
        std::thread::sleep(Duration::from_millis(20));
    }

    // the session recovers with a plain USE…
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = c.request("USE asia").unwrap();
        if r.starts_with("OK using asia") {
            break;
        }
        assert!(r.starts_with("ERR"), "{r}");
        assert!(Instant::now() < deadline, "USE never recovered: {r}");
        std::thread::sleep(Duration::from_millis(50));
    }
    // …and the dead backend's committed evidence died with it: the fresh
    // tree answers the prior, not a stale-evidence posterior
    assert!(c.request("QUERY lung").unwrap().starts_with("OK yes=0.055000"), "stale evidence was misapplied");
    assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));

    // health surfaces agree — one backend dead, one alive
    let ping = c.request("PING").unwrap();
    assert!(ping.contains("backends=2 alive=1"), "{ping}");
    let stats = c.request("STATS").unwrap();
    assert!(stats.contains("alive=1"), "{stats}");
    let topo = c.request("TOPO").unwrap();
    assert!(topo.contains(&format!("{victim}[addr=")) && topo.contains("alive=false"), "{topo}");

    // cancer is reachable from a fresh session wherever it lives now
    let mut c2 = harness.client().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = c2.request("USE cancer").unwrap();
        if r.starts_with("OK using cancer") {
            break;
        }
        assert!(Instant::now() < deadline, "cancer never recovered: {r}");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(c2.request("QUERY Cancer | Smoker=True").unwrap().starts_with("OK True=0.032000"));
}

#[test]
fn cluster_cli_smoke_runs_end_to_end() {
    // the real multi-process path: `fastbn cluster` spawns backend child
    // processes, joins them, and drives the scripted session
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_fastbn"))
        .args([
            "cluster", "--backends", "2", "--nets", "asia,cancer", "--engine", "seq", "--threads", "1",
            "--shards", "1", "--bind", "127.0.0.1:0", "--smoke",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match child.try_wait().unwrap() {
            Some(_) => break,
            None if Instant::now() >= deadline => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("`fastbn cluster --smoke` did not finish within 120s");
            }
            None => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let output = child.wait_with_output().unwrap();
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(output.status.success(), "exit={:?}\nstdout:\n{stdout}\nstderr:\n{stderr}", output.status);
    assert!(stdout.contains("cluster-smoke passed (2 backends"), "stdout:\n{stdout}");
}

#[test]
fn batch_verb_passes_through_the_front_tier() {
    // a batched-engine backend behind the router: the BATCH/CASE dance
    // must round-trip the front tier with the same replies the backend's
    // own socket would produce — including the n-line final reply
    let harness = ClusterHarness::start(
        2,
        FleetConfig {
            engine: EngineKind::Batched,
            engine_cfg: EngineConfig::default().with_threads(1).with_batch(3),
            shards: 1,
            registry_capacity: 8,
            max_exact_cost: f64::INFINITY,
        },
        fast_cluster_cfg(),
    )
    .unwrap();
    let mut c = harness.client().unwrap();
    assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
    let want_yes = c.request("QUERY lung | smoke=yes").unwrap();
    let want_prior = c.request("QUERY lung").unwrap();

    assert_eq!(c.request("BATCH 3 lung").unwrap(), "OK batch expect=3 target=lung");
    assert_eq!(c.request("CASE smoke=yes").unwrap(), "OK case 1/3");
    assert_eq!(c.request("CASE").unwrap(), "OK case 2/3");
    let results = c.request_lines("CASE smoke=yes", 3).unwrap();
    assert_eq!(results, vec![want_yes.clone(), want_prior, want_yes]);

    // the session (front and backend) is clean afterwards: plain verbs
    // keep working and a stray CASE is rejected, not miscounted
    assert!(c.request("CASE").unwrap().starts_with("ERR no batch in progress"));
    assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));

    // a verb the front answers locally — including a USE it rejects
    // without touching the pinned conn — must NOT desync the countdown:
    // the backend never saw a verb, so the batch stays open on both tiers
    assert!(c.request("BATCH 2 lung").unwrap().starts_with("OK batch expect=2"));
    assert_eq!(c.request("CASE smoke=yes").unwrap(), "OK case 1/2");
    assert!(c.request("NETS").unwrap().starts_with("OK nets="));
    assert!(c.request("USE not-loaded-anywhere").unwrap().starts_with("ERR not loaded"));
    let tail = c.request_lines("CASE", 2).unwrap();
    assert!(tail[0].starts_with("OK yes=0.100000"), "{}", tail[0]);
    assert!(tail[1].starts_with("OK yes=0.055000"), "{}", tail[1]);

    // a forwarded non-CASE verb aborts an open batch on both tiers
    assert!(c.request("BATCH 2 lung").unwrap().starts_with("OK batch expect=2"));
    assert_eq!(c.request("CASE smoke=yes").unwrap(), "OK case 1/2");
    assert!(c.request("QUERY lung").unwrap().starts_with("OK yes=0.055000"));
    assert!(c.request("CASE").unwrap().starts_with("ERR no batch in progress"));
}

#[test]
fn replicated_owners_survive_killing_any_single_backend() {
    // R=2: every net lives on two backends, so killing one owner must
    // lose nothing — clean sessions keep getting byte-identical answers
    // with zero error replies, and the ring re-homes every net onto the
    // survivors.
    let cfg = ClusterConfig { replicas: 2, ..fast_cluster_cfg() };
    let mut harness = ClusterHarness::start(3, backend_cfg(), cfg).unwrap();
    let mut admin = harness.client().unwrap();
    for name in ["asia", "cancer", "mixed12"] {
        let r = admin.request(&format!("LOAD {name}")).unwrap();
        assert!(r.starts_with("OK loaded"), "{r}");
        assert!(r.contains("replicas=2"), "{r}");
        assert_eq!(harness.cluster().replicas_of(name).len(), 2, "{name} not replicated");
    }

    // a clean session reading asia, and a dirty one pinned to its primary
    let mut clean = harness.client().unwrap();
    assert!(clean.request("USE asia").unwrap().starts_with("OK using asia"));
    let want = clean.request("QUERY lung").unwrap();
    assert!(want.starts_with("OK yes=0.055000"), "{want}");

    let mut dirty = harness.client().unwrap();
    assert!(dirty.request("USE asia").unwrap().starts_with("OK using asia"));
    assert!(dirty.request("OBSERVE smoke=yes").unwrap().starts_with("OK staged 1"));
    assert!(dirty.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));
    assert!(dirty.request("QUERY lung").unwrap().starts_with("OK yes=0.100000"));

    let victim = harness.cluster().owner("asia").unwrap();
    assert!(harness.kill_backend(&victim));

    // the clean session never sees the death: the dead replica's reads
    // fail over inside the front and every reply stays byte-identical
    for i in 0..8 {
        let r = clean.request("QUERY lung").unwrap();
        assert_eq!(r, want, "clean read {i} diverged after killing {victim}");
    }

    // no net is lost: every name heals back to two live owners
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let healed = ["asia", "cancer", "mixed12"].iter().all(|&n| {
            let owners = harness.cluster().replicas_of(n);
            owners.len() == 2 && !owners.contains(&victim)
        });
        if healed {
            break;
        }
        assert!(Instant::now() < deadline, "replicas never re-homed after killing {victim}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // the dirty session cannot be silently rerouted — its committed
    // evidence lived only on the victim — so it errors cleanly, then
    // recovers to the evidence-free prior after an explicit USE
    let r = dirty.request("QUERY lung").unwrap();
    assert!(r.starts_with("ERR"), "{r}");
    assert!(r.contains("unreachable") || r.contains("moved"), "{r}");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = dirty.request("USE asia").unwrap();
        if r.starts_with("OK using asia") {
            break;
        }
        assert!(r.starts_with("ERR"), "{r}");
        assert!(Instant::now() < deadline, "USE never recovered: {r}");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(dirty.request("QUERY lung").unwrap().starts_with("OK yes=0.055000"), "stale evidence was misapplied");
}

#[test]
fn clean_session_batch_replays_on_a_survivor_mid_collection() {
    // a clean session's BATCH is buffered verbatim at the front; when the
    // collecting backend dies between CASEs, the buffered prefix replays
    // on the other replica and the client never sees an error
    let cfg = ClusterConfig { replicas: 2, ..fast_cluster_cfg() };
    let harness_cfg = FleetConfig {
        engine: EngineKind::Batched,
        engine_cfg: EngineConfig::default().with_threads(1).with_batch(3),
        shards: 1,
        registry_capacity: 8,
        max_exact_cost: f64::INFINITY,
    };
    let mut harness = ClusterHarness::start(2, harness_cfg, cfg).unwrap();
    let mut probe = harness.client().unwrap();
    assert!(probe.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    assert!(probe.request("USE asia").unwrap().starts_with("OK using asia"));
    let want_yes = probe.request("QUERY lung | smoke=yes").unwrap();
    let want_prior = probe.request("QUERY lung").unwrap();

    // a fresh client's first spread op lands on the primary owner, so the
    // batch is collected by a known victim
    let mut c = harness.client().unwrap();
    assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
    assert_eq!(c.request("BATCH 3 lung").unwrap(), "OK batch expect=3 target=lung");
    assert_eq!(c.request("CASE smoke=yes").unwrap(), "OK case 1/3");

    let victim = harness.cluster().owner("asia").unwrap();
    assert!(harness.kill_backend(&victim));

    // the remaining cases replay the buffered prefix on the survivor:
    // same acks, same final 3-line reply, no error in between
    assert_eq!(c.request("CASE").unwrap(), "OK case 2/3");
    let results = c.request_lines("CASE smoke=yes", 3).unwrap();
    assert_eq!(results, vec![want_yes.clone(), want_prior, want_yes]);

    // and the session is clean and usable afterwards
    assert!(c.request("CASE").unwrap().starts_with("ERR no batch in progress"));
    assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));
}

#[test]
fn handoff_replays_a_session_on_the_peer_front() {
    // router redundancy: a second front derives the same placement from
    // the deterministic ring, and HANDOFF exports a session's committed
    // evidence so the client can replay it there after the primary
    // router dies
    let mut harness = ClusterHarness::start(2, backend_cfg(), fast_cluster_cfg()).unwrap();
    let mut c = harness.client().unwrap();
    assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));

    // export with nothing selected is refused up front
    let mut idle = harness.client().unwrap();
    assert!(idle.request("HANDOFF").unwrap().starts_with("ERR no network selected"));

    assert!(c.request("OBSERVE smoke=yes").unwrap().starts_with("OK staged 1"));
    assert!(c.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));
    let want = c.request("QUERY lung").unwrap();
    assert!(want.starts_with("OK yes=0.100000"), "{want}");

    // positional export format: `OK handoff net=<net> evidence=<k> [pairs…]`
    let export = c.request("HANDOFF").unwrap();
    let toks: Vec<&str> = export.split_whitespace().collect();
    assert_eq!(&toks[..4], &["OK", "handoff", "net=asia", "evidence=1"], "{export}");
    let pairs = toks[4..].join(" ");
    assert_eq!(pairs, "smoke=yes", "{export}");

    harness.start_peer_front().unwrap();
    assert!(harness.kill_primary_front());

    let mut p = harness.peer_client().unwrap();
    // malformed payloads are rejected before any backend I/O
    assert!(p.request("HANDOFF asia notapair").unwrap().starts_with("ERR usage: HANDOFF"));
    let r = p.request(&format!("HANDOFF asia {pairs}")).unwrap();
    assert_eq!(r, "OK handoff applied net=asia evidence=1");
    // the replayed session answers byte-identically to the pre-kill one
    assert_eq!(p.request("QUERY lung").unwrap(), want);

    // and the peer is a full front in its own right: a fresh clean
    // session reads the evidence-free prior
    let mut fresh = harness.peer_client().unwrap();
    assert!(fresh.request("USE asia").unwrap().starts_with("OK using asia"));
    assert!(fresh.request("QUERY lung").unwrap().starts_with("OK yes=0.055000"));
}
