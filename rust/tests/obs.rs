//! Integration: the observability layer end to end — registry exposition
//! over a live fleet socket, the cluster-wide scrape merge, the
//! slow-query log, the pool parallelism profiler, cluster-correlated
//! query tracing, and the guarantee that telemetry never changes a reply.
//!
//! The trace/profiler toggles (`TRACE on`, `PROFILE on`, the slow
//! threshold) are process-wide; every test that flips one serializes on
//! [`TOGGLE`] and keys its assertions on span/region names unique to
//! that test, so the suite stays order- and parallelism-independent.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use fastbn::cluster::{BackendConn, ClusterConfig, ClusterHarness};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::fleet::{Fleet, FleetConfig, FleetServer};
use fastbn::obs::registry::{bucket_bound, BUCKETS};
use fastbn::obs::{scrape, series, trace, Registry};

/// Serializes the tests that flip process-wide trace toggles.
static TOGGLE: Mutex<()> = Mutex::new(());

fn fleet_cfg() -> FleetConfig {
    FleetConfig {
        engine: EngineKind::Seq,
        engine_cfg: EngineConfig::default().with_threads(1),
        shards: 1,
        registry_capacity: 8,
        max_exact_cost: f64::INFINITY,
    }
}

fn connect(addr: std::net::SocketAddr) -> BackendConn {
    BackendConn::connect(addr, Duration::from_secs(1), Duration::from_secs(10)).unwrap()
}

#[test]
fn registry_renders_the_exact_exposition() {
    let r = Registry::default();
    r.counter(&series("fastbn_test_total", &[("net", "a")])).add(3);
    r.counter(&series("fastbn_test_total", &[("net", "b")])).inc();
    r.register_gauge("fastbn_test_active", || 7);
    r.histogram(&series("fastbn_test_us", &[("net", "a")])).record_value(3);

    let mut want: Vec<String> = vec![
        "# TYPE fastbn_test_total counter".into(),
        "fastbn_test_total{net=\"a\"} 3".into(),
        "fastbn_test_total{net=\"b\"} 1".into(),
        "# TYPE fastbn_test_active gauge".into(),
        "fastbn_test_active 7".into(),
        "# TYPE fastbn_test_us histogram".into(),
    ];
    for i in 0..BUCKETS {
        let le = if i + 1 < BUCKETS { format!("{}", 1u64 << i) } else { "+Inf".into() };
        // the single observation (3) lands in the le=4 bucket (index 2)
        let cum = if bucket_bound(i) >= 4 { 1 } else { 0 };
        want.push(format!("fastbn_test_us_bucket{{net=\"a\",le=\"{le}\"}} {cum}"));
    }
    want.push("fastbn_test_us_sum{net=\"a\"} 3".into());
    want.push("fastbn_test_us_count{net=\"a\"} 1".into());
    assert_eq!(r.render(), want.join("\n"));
    assert_eq!(r.render(), r.render(), "render must be deterministic");
}

#[test]
fn histogram_percentiles_bound_the_true_values() {
    let h = fastbn::obs::Histogram::default();
    let mut samples = vec![10u64, 30, 100, 300, 1000, 3000, 10000, 30000, 100000];
    for v in &samples {
        h.record_value(*v);
    }
    samples.sort_unstable();
    let n = samples.len();
    let mut prev = 0u64;
    for p in [0.50, 0.90, 0.99] {
        let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
        let truth = samples[rank - 1];
        let got = h.percentile(p);
        assert!(got >= truth, "p{p}: bucket bound {got} below true value {truth}");
        assert!(got <= 2 * truth, "p{p}: bucket bound {got} beyond 2x true value {truth}");
        assert!(got >= prev, "percentiles must be monotone");
        prev = got;
    }
}

#[test]
fn metrics_and_trace_round_trip_over_a_live_socket() {
    let server = FleetServer::start(Arc::new(Fleet::new(fleet_cfg())), "127.0.0.1:0").unwrap();
    let mut conn = connect(server.addr());
    conn.request("LOAD asia").unwrap();
    conn.request("LOAD cancer").unwrap();
    conn.request("USE asia").unwrap();
    // interleaved queries: two against asia, one against cancer — the
    // exposition must show exactly those per-net counts
    assert!(conn.request("QUERY dysp | smoke=yes").unwrap().starts_with("OK "));
    assert!(conn.request("QUERY dysp").unwrap().starts_with("OK "));
    conn.request("USE cancer").unwrap();
    let cancer = fastbn::bn::embedded::by_name("cancer").unwrap();
    let target = &cancer.vars[cancer.n() - 1].name;
    assert!(conn.request(&format!("QUERY {target}")).unwrap().starts_with("OK "));

    let (header, body) = conn.request_block("METRICS").unwrap();
    assert!(header.starts_with("OK metrics lines="), "{header}");
    let text = body.join("\n");
    assert_eq!(body.len(), text.lines().count(), "no blank lines inside the block");
    assert_eq!(scrape::value(&text, "fastbn_queries_total{net=\"asia\"}"), Some(2), "{text}");
    assert_eq!(scrape::value(&text, "fastbn_queries_total{net=\"cancer\"}"), Some(1), "{text}");
    assert_eq!(scrape::value(&text, "fastbn_query_latency_us_count{net=\"asia\"}"), Some(2), "{text}");
    assert_eq!(scrape::value(&text, "fastbn_query_latency_us_bucket{net=\"asia\",le=\"+Inf\"}"), Some(2), "{text}");
    assert_eq!(scrape::value(&text, "fastbn_query_errors_total{net=\"asia\"}"), None, "no error series before errors");

    // the TRACE verb drives the process-wide toggle: serialize
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    assert_eq!(conn.request("TRACE on").unwrap(), "OK trace on");
    assert!(conn.request("QUERY dysp | smoke=yes").unwrap().starts_with("OK "));
    let replay = conn.request("TRACE last").unwrap();
    assert!(replay.starts_with("OK trace total_us="), "{replay}");
    assert!(replay.contains("shard.infer="), "{replay}");
    assert_eq!(conn.request("TRACE off").unwrap(), "OK trace off");
    assert!(conn.request("TRACE bogus").unwrap().starts_with("ERR usage: TRACE"));
    server.shutdown();
}

#[test]
fn cluster_scrape_merges_the_backend_expositions() {
    let h = ClusterHarness::start(
        2,
        fleet_cfg(),
        ClusterConfig {
            vnodes: 64,
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(5),
            probe_timeout: Duration::from_millis(500),
            probe_interval: Duration::from_millis(100),
            probe_backoff_max: Duration::from_secs(1),
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = h.client().unwrap();
    assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    assert!(c.request("LOAD cancer").unwrap().starts_with("OK loaded cancer"));
    c.request("USE asia").unwrap();
    assert!(c.request("QUERY dysp | smoke=yes").unwrap().starts_with("OK "));
    assert!(c.request("QUERY dysp").unwrap().starts_with("OK "));
    c.request("USE cancer").unwrap();
    let cancer = fastbn::bn::embedded::by_name("cancer").unwrap();
    let target = &cancer.vars[cancer.n() - 1].name;
    assert!(c.request(&format!("QUERY {target}")).unwrap().starts_with("OK "));

    let mut front = connect(h.front_addr());
    let (header, body) = front.request_block("METRICS").unwrap();
    assert!(header.starts_with("OK metrics backends=2 lines="), "{header}");
    let merged = body.join("\n");

    // every alive backend contributes labeled series (the connection and
    // LRU gauges exist on every fleet, so no backend scrapes empty) …
    for id in h.live_backend_ids() {
        assert!(merged.contains(&format!("backend=\"{id}\"")), "no series labeled backend=\"{id}\":\n{merged}");
    }
    // … and every per-net aggregate equals the sum of the backends' own
    // expositions, bucket-wise for histograms. (Only per-net series are
    // compared: the in-process harness shares one global registry, which
    // the merge would double-count across backends.)
    let parts: Vec<String> =
        h.live_backend_ids().iter().map(|id| h.backend_fleet(id).unwrap().metrics_exposition()).collect();
    for key in [
        "fastbn_queries_total{net=\"asia\"}",
        "fastbn_queries_total{net=\"cancer\"}",
        "fastbn_query_latency_us_count{net=\"asia\"}",
        "fastbn_query_latency_us_count{net=\"cancer\"}",
        "fastbn_query_latency_us_bucket{net=\"asia\",le=\"+Inf\"}",
        "fastbn_query_latency_us_bucket{net=\"cancer\",le=\"+Inf\"}",
    ] {
        let want: u64 = parts.iter().map(|p| scrape::value(p, key).unwrap_or(0)).sum();
        assert!(want > 0, "no backend recorded {key}");
        assert_eq!(scrape::value(&merged, key), Some(want), "merged {key} is not the backend sum");
    }
}

#[test]
fn slow_query_log_captures_only_queries_over_the_threshold() {
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    trace::set_slow_query_us(200_000);
    {
        let root = trace::span("obs-slow-probe");
        root.note("deliberate");
        std::thread::sleep(Duration::from_millis(250));
    }
    {
        let _root = trace::span("obs-fast-probe");
        std::thread::sleep(Duration::from_millis(5));
    }
    trace::set_slow_query_us(0);
    let slow = trace::slow_queries();
    let roots: Vec<&str> = slow.iter().filter_map(|t| t.root().map(|s| s.name)).collect();
    assert!(roots.contains(&"obs-slow-probe"), "slow query missing from the log: {roots:?}");
    assert!(!roots.contains(&"obs-fast-probe"), "fast query leaked into the slow log: {roots:?}");
    let ours = slow.iter().find(|t| t.root().map(|s| s.name) == Some("obs-slow-probe")).unwrap();
    assert!(ours.total_us >= 200_000, "total_us={}", ours.total_us);
    assert!(ours.render().contains("[deliberate]"), "{}", ours.render());
}

#[test]
fn tracing_never_changes_a_reply_byte() {
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    trace::set_enabled(false);
    trace::set_slow_query_us(0);
    let server = FleetServer::start(Arc::new(Fleet::new(fleet_cfg())), "127.0.0.1:0").unwrap();
    let mut conn = connect(server.addr());
    conn.request("LOAD asia").unwrap();
    conn.request("USE asia").unwrap();
    let q = "QUERY dysp | smoke=yes";

    let off = conn.request(q).unwrap();
    trace::set_enabled(true);
    let on = conn.request(q).unwrap();
    trace::set_slow_query_us(1); // everything is "slow": the heaviest instrumented path
    let slow = conn.request(q).unwrap();
    trace::set_enabled(false);
    trace::set_slow_query_us(0);

    assert!(off.starts_with("OK "), "{off}");
    assert_eq!(off, on, "enabling tracing changed the reply");
    assert_eq!(off, slow, "the slow-query path changed the reply");
    server.shutdown();
}

/// A fleet whose shards run the hybrid engine on a real 2-thread pool —
/// the configuration whose parallel regions the profiler instruments.
fn hybrid_fleet_cfg() -> FleetConfig {
    FleetConfig {
        engine: EngineKind::Hybrid,
        engine_cfg: EngineConfig::default().with_threads(2),
        ..fleet_cfg()
    }
}

#[test]
fn profiler_never_changes_a_reply_byte() {
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    fastbn::obs::profile::set_armed(false);
    let server = FleetServer::start(Arc::new(Fleet::new(hybrid_fleet_cfg())), "127.0.0.1:0").unwrap();
    let mut conn = connect(server.addr());
    conn.request("LOAD asia").unwrap();
    conn.request("USE asia").unwrap();
    let q = "QUERY dysp | smoke=yes";

    let off = conn.request(q).unwrap();
    assert!(off.starts_with("OK "), "{off}");
    // arm over the wire — the same toggle the PROFILE verb flips
    assert_eq!(conn.request("PROFILE on").unwrap(), "OK profile on");
    let on = conn.request(q).unwrap();
    assert_eq!(conn.request("PROFILE off").unwrap(), "OK profile off");
    let off_again = conn.request(q).unwrap();

    assert_eq!(off, on, "arming the profiler changed the reply");
    assert_eq!(off, off_again, "disarming the profiler did not restore the reply");
    assert!(conn.request("PROFILE bogus").unwrap().starts_with("ERR usage: PROFILE"));
    server.shutdown();
}

#[test]
fn armed_hybrid_profile_accounts_busy_plus_idle_per_lane() {
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let server = FleetServer::start(Arc::new(Fleet::new(hybrid_fleet_cfg())), "127.0.0.1:0").unwrap();
    let mut conn = connect(server.addr());
    conn.request("LOAD asia").unwrap();
    conn.request("USE asia").unwrap();
    assert_eq!(conn.request("PROFILE on").unwrap(), "OK profile on");
    for _ in 0..3 {
        assert!(conn.request("QUERY dysp | smoke=yes").unwrap().starts_with("OK "));
    }
    let snap = fastbn::obs::profile::snapshot();
    assert_eq!(conn.request("PROFILE off").unwrap(), "OK profile off");

    let regions: Vec<&str> = snap.iter().map(|p| p.region).collect();
    let hybrid: Vec<_> = snap.iter().filter(|p| p.region.starts_with("hybrid.")).collect();
    assert!(!hybrid.is_empty(), "no hybrid.* regions profiled: {regions:?}");
    for p in &hybrid {
        assert!(p.entries > 0, "region {} recorded no entries", p.region);
        assert!(p.tasks.iter().sum::<u64>() > 0, "region {} ran no tasks", p.region);
        // per-lane accounting: busy + derived idle reproduces the region
        // wall — exact when busy ≤ wall, with a small one-sided slop for
        // clock truncation on the armed path's per-task Instant reads
        let idle = p.idle_us();
        for (lane, (b, i)) in p.busy_us.iter().zip(&idle).enumerate() {
            let sum = b + i;
            assert!(sum >= p.wall_us, "lane {lane} of {}: busy+idle {sum} < wall {}", p.region, p.wall_us);
            assert!(sum <= p.wall_us + 2_000, "lane {lane} of {}: busy+idle {sum} overshoots wall {}", p.region, p.wall_us);
        }
        let imb = p.imbalance();
        assert!(imb >= 1.0 - 1e-9 && imb <= p.workers() as f64 + 1e-9, "region {}: imbalance {imb}", p.region);
    }
    server.shutdown();
}

#[test]
fn cluster_trace_qid_returns_one_cross_tier_timeline_under_replication() {
    let _serialized = TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let h = ClusterHarness::start(
        2,
        fleet_cfg(),
        ClusterConfig {
            replicas: 2,
            vnodes: 64,
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(5),
            probe_timeout: Duration::from_millis(500),
            probe_interval: Duration::from_millis(100),
            probe_backoff_max: Duration::from_secs(1),
            ..Default::default()
        },
    )
    .unwrap();
    let mut c = h.client().unwrap();
    assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
    c.request("USE asia").unwrap();
    assert_eq!(c.request("TRACE on").unwrap(), "OK trace on backends=2");

    let reply = c.request("QUERY dysp | smoke=yes").unwrap();
    assert!(reply.starts_with("OK "), "{reply}");
    let qid = reply
        .split_whitespace()
        .rev()
        .find_map(|t| t.strip_prefix("qid="))
        .unwrap_or_else(|| panic!("armed cluster QUERY reply carries no qid=: {reply:?}"))
        .to_string();

    // with R=2 both owners could answer for the net, but TRACE <qid>
    // assembles exactly one merged timeline: one backend tag, one span
    // tree, prefixed with the front's own routing view
    let timeline = c.request(&format!("TRACE {qid}")).unwrap();
    assert!(timeline.starts_with(&format!("OK trace qid={qid} net=asia backend=\"")), "{timeline}");
    assert!(timeline.contains(" route_us="), "{timeline}");
    assert!(timeline.contains(" total_us="), "{timeline}");
    assert_eq!(timeline.matches("backend=\"").count(), 1, "more than one timeline: {timeline}");
    assert_eq!(timeline.matches(" total_us=").count(), 1, "more than one span tree: {timeline}");

    // unknown ids are a clean error; junk stays a usage error
    assert!(c.request("TRACE q999983").unwrap().starts_with("ERR no trace recorded for qid"));
    assert!(c.request("TRACE qabc").unwrap().starts_with("ERR usage: TRACE"));
    assert_eq!(c.request("TRACE off").unwrap(), "OK trace off backends=2");
    trace::set_enabled(false);
}
