//! Integration: the batch coordinator and TCP server over a paper-suite
//! network analog — the serving loop end to end.

use std::sync::Arc;

use fastbn::bn::netgen;
use fastbn::coordinator::{BatchConfig, BatchRunner};
use fastbn::coordinator::server::Server;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

#[test]
fn batch_over_hailfinder_analog_all_engines_agree() {
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: 12, observed_fraction: 0.2, seed: 2023 });
    let runner = BatchRunner::new(Arc::clone(&jt));

    let mut reports = Vec::new();
    for kind in EngineKind::ALL {
        let cfg = BatchConfig {
            engine: kind,
            engine_cfg: EngineConfig { threads: 2, ..Default::default() },
            replicas: 1,
            fused_batch: 0,
        };
        let report = runner.run(&cases, &cfg).unwrap();
        assert_eq!(
            report.latency.count + report.failures.len(),
            cases.len(),
            "{kind}: lost cases"
        );
        reports.push((kind, report));
    }
    // identical failure sets and matching mean log-likelihood
    let (k0, r0) = &reports[0];
    for (kind, r) in &reports[1..] {
        assert_eq!(r.failures.len(), r0.failures.len(), "{kind} vs {k0}");
        assert!(
            (r.mean_log_z - r0.mean_log_z).abs() < 1e-9,
            "{kind}: mean_log_z {} vs {} ({k0})",
            r.mean_log_z,
            r0.mean_log_z
        );
    }
}

#[test]
fn replica_scaling_preserves_results() {
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let cases = generate(&net, &CaseSpec { n_cases: 16, observed_fraction: 0.2, seed: 31 });
    let runner = BatchRunner::new(Arc::clone(&jt));
    let mk = |replicas| BatchConfig {
        engine: EngineKind::Hybrid,
        engine_cfg: EngineConfig { threads: 1, ..Default::default() },
        replicas,
        fused_batch: 0,
    };
    let r1 = runner.run(&cases, &mk(1)).unwrap();
    let r4 = runner.run(&cases, &mk(4)).unwrap();
    assert_eq!(r1.latency.count, r4.latency.count);
    assert!((r1.mean_log_z - r4.mean_log_z).abs() < 1e-9);
}

#[test]
fn server_round_trip_on_generated_network() {
    use std::io::{BufRead, BufReader, Write};
    let net = netgen::paper_net("hailfinder-sim").unwrap();
    let target = net.vars[0].name.clone();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
    let server = Server::start(
        jt,
        EngineKind::Hybrid,
        EngineConfig { threads: 2, ..Default::default() },
        "127.0.0.1:0",
    )
    .unwrap();

    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(format!("QUERY {target}\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    // probabilities in the reply must sum to ~1
    let sum: f64 = line
        .split_whitespace()
        .filter_map(|tok| tok.split_once('=').and_then(|(k, v)| if k == "logZ" { None } else { v.parse::<f64>().ok() }))
        .sum();
    assert!((sum - 1.0).abs() < 1e-3, "posterior sums to {sum}: {line}");
    server.shutdown();
}
