# Fast-BNI reproduction — build/test/bench entry points.
#
#   make build      release build of the fastbn crate (pure-std, offline-safe)
#   make test       tier-1: cargo test; then the python suite (skips if no pytest)
#   make bench      run all four bench targets (criterion-lite, harness=false)
#   make artifacts  AOT-lower the Pallas/JAX kernels to HLO-text artifacts
#                   (needs the python deps in python/requirements.txt)
#   make fmt        rustfmt the workspace
#   make lint       clippy with warnings denied
#   make test-xla   build artifacts, then run the xla-feature test suite
#                   (exercises PJRT only when the real xla crate replaces
#                   the vendored stub — see rust/vendor/xla-stub)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench artifacts fmt lint test-xla clean

build:
	$(CARGO) build --release

# python suite: exit 5 = no tests collected (conftest skipped the suite
# because the JAX stack is missing) — a skip, not a failure. Any other
# nonzero exit is a real failure and fails `make test`.
test: build
	$(CARGO) test -q
	@if $(PYTHON) -c "import pytest" 2>/dev/null; then \
		$(PYTHON) -m pytest python/ -q; rc=$$?; \
		if [ $$rc -eq 5 ]; then echo "python suite skipped (no tests collected — JAX unavailable)"; \
		elif [ $$rc -ne 0 ]; then exit $$rc; fi; \
	else \
		echo "python suite skipped (pytest not installed)"; \
	fi

bench:
	$(CARGO) bench

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

fmt:
	$(CARGO) fmt --all

lint:
	$(CARGO) clippy --all-targets -- -D warnings

test-xla: artifacts
	$(CARGO) test -q --features xla

clean:
	$(CARGO) clean
	rm -rf artifacts
