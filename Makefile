# Fast-BNI reproduction — build/test/bench entry points.
#
#   make build      release build of the fastbn crate (pure-std, offline-safe)
#   make test       tier-1: cargo test; then the python suite (skips if no pytest)
#   make bench      run all eleven bench targets (criterion-lite, harness=false)
#   make bench-json refresh the perf-trajectory artifacts: BENCH_approx.json
#                   (approx-tier sample-count × thread sweep vs the exact
#                   engine), BENCH_kernels.json (lane micro-kernel sweep,
#                   blocked SIMD drivers vs their scalar twins), and
#                   BENCH_obs.json (tracer/profiler armed-vs-disarmed
#                   query-path overhead)
#   make kernel-smoke run the kernel bit-exactness suites (lane kernels,
#                   case-major ops, batched MPE vs single-case) under both
#                   the default `simd` feature and --no-default-features
#   make serve-smoke start a 2-network fleet, run a scripted session
#                   through it over TCP, and assert on the replies
#   make batch-smoke drive the BATCH verb (N evidence lines in, N posterior
#                   lines out, one fused sweep) through a live fleet socket
#   make cluster-smoke spawn 2 fleet backend processes + the consistent-hash
#                   front tier, run a scripted session through the router
#   make learn-smoke sample->learn->serve->QUERY round trip over a live
#                   fleet socket (LEARN verb), learned twice to assert the
#                   deterministic-relearn contract
#   make approx-smoke LOAD an intractable net into a live fleet with a finite
#                   --max-exact-cost and assert it is served by the approximate
#                   tier (tier=approx + ci95 half-widths in the replies) while
#                   a tractable net stays exact
#   make metrics-smoke drive the observability surface end to end: QUERYs
#                   into a live fleet then METRICS/TRACE over the same
#                   socket (counters and histogram counts must match the
#                   queries), then a 2-backend cluster whose front-tier
#                   METRICS must merge every backend's scrape
#   make profile-smoke drive the parallelism profiler + correlated tracing:
#                   PROFILE on a live hybrid fleet (per-worker busy lanes,
#                   imbalance within the worker bound), then a 2-backend
#                   cluster front that mints qids and replays one query's
#                   cross-tier timeline via TRACE q<n>
#   make artifacts  AOT-lower the Pallas/JAX kernels to HLO-text artifacts
#                   (needs the python deps in python/requirements.txt)
#   make fmt        rustfmt the workspace
#   make lint       clippy with warnings denied
#   make test-xla   build artifacts, then run the xla-feature test suite
#                   (exercises PJRT only when the real xla crate replaces
#                   the vendored stub — see rust/vendor/xla-stub)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-json kernel-smoke serve-smoke batch-smoke cluster-smoke learn-smoke approx-smoke metrics-smoke profile-smoke artifacts fmt lint test-xla clean

build:
	$(CARGO) build --release

# python suite: exit 5 = no tests collected (conftest skipped the suite
# because the JAX stack is missing) — a skip, not a failure. Any other
# nonzero exit is a real failure and fails `make test`.
test: build
	$(CARGO) test -q
	@if $(PYTHON) -c "import pytest" 2>/dev/null; then \
		$(PYTHON) -m pytest python/ -q; rc=$$?; \
		if [ $$rc -eq 5 ]; then echo "python suite skipped (no tests collected — JAX unavailable)"; \
		elif [ $$rc -ne 0 ]; then exit $$rc; fi; \
	else \
		echo "python suite skipped (pytest not installed)"; \
	fi

bench:
	$(CARGO) bench

# perf-trajectory artifacts: the approx bench writes its sweep (cost +
# accuracy vs the exact engine), the kernels bench its lane micro-kernel
# sweep (blocked SIMD drivers vs scalar twins), and the obs bench its
# telemetry-overhead sweep (tracer/profiler armed vs disarmed) as
# stable-schema JSON. CI regenerates and uploads all three on every push;
# the committed copies are the schema baselines.
bench-json:
	FASTBN_BENCH_JSON=$(CURDIR)/BENCH_approx.json $(CARGO) bench --bench approx
	FASTBN_BENCH_JSON=$(CURDIR)/BENCH_kernels.json $(CARGO) bench --bench kernels
	FASTBN_BENCH_JSON=$(CURDIR)/BENCH_obs.json $(CARGO) bench --bench obs

# kernel bit-exactness smoke: the lane-kernel, case-major-ops, and
# batched-MPE suites pin the SIMD path byte-for-byte against the scalar
# path; run them under both feature configurations so neither side rots.
kernel-smoke:
	$(CARGO) test -q -- bit_identical batched_mpe
	$(CARGO) test -q --no-default-features -- bit_identical batched_mpe

# fleet serving smoke: 2 networks × 2 shards on an ephemeral port; the
# --smoke switch drives a scripted LOAD/USE/OBSERVE/COMMIT/QUERY/STATS
# session through the server's own socket and exits nonzero on any
# unexpected reply.
serve-smoke:
	$(CARGO) run --release -- serve --nets asia,cancer --shards 2 --bind 127.0.0.1:0 --smoke

# BATCH-verb smoke: a batched-engine fleet on an ephemeral port; the
# --batch-smoke switch drives BATCH/CASE through the server's own socket
# (N evidence lines in, N posterior lines out, one shard dispatch) and
# asserts the replies are byte-identical to the equivalent QUERYs.
batch-smoke:
	$(CARGO) run --release -- serve --nets asia,cancer --engine batched --batch 4 --shards 1 --bind 127.0.0.1:0 --batch-smoke

# cluster serving smoke: 2 backend fleet *processes* (spawned as children
# announcing ephemeral ports) behind the consistent-hash front tier; the
# --smoke switch drives a scripted LOAD/USE/OBSERVE/COMMIT/QUERY/STATS/
# TOPO/HANDOFF session through the router (including a malformed-JOIN
# rejection) and exits nonzero on any unexpected reply. Replication and
# router failover are exercised by `cargo test --test cluster`.
cluster-smoke:
	$(CARGO) run --release -- cluster --backends 2 --nets asia,cancer --bind 127.0.0.1:0 --smoke

# learning smoke: an empty fleet on an ephemeral port; the --learn-smoke
# switch drives LEARN/USE/QUERY through the server's own socket (sample
# from asia, learn structure + parameters, serve the learned net), learns
# the identical spec twice, and asserts the two nets answer QUERY
# byte-identically.
learn-smoke:
	$(CARGO) run --release -- serve --fleet --shards 1 --bind 127.0.0.1:0 --learn-smoke

# approximate-tier smoke: an empty fleet with a finite exact-cost budget;
# the --approx-smoke switch LOADs intractable-sim (whose estimated
# junction-tree cost blows the budget) plus asia through the server's own
# socket and asserts the intractable net answers QUERY from the approx
# tier — deterministically, with ci95/ess in the reply — while asia keeps
# the exact tier in LOAD/NETS/STATS.
approx-smoke:
	$(CARGO) run --release -- serve --fleet --shards 1 --samples 20000 --max-exact-cost 1e6 --bind 127.0.0.1:0 --approx-smoke

# observability smoke, both tiers. Fleet: --metrics-smoke drives QUERYs
# then METRICS/TRACE through the server's own socket and asserts the
# per-net counter and latency-histogram count equal the query count and
# that TRACE replays the last span tree. Cluster: the front tier's
# METRICS must scrape both backend processes and merge their expositions
# (per-backend labels + summed aggregates).
metrics-smoke:
	$(CARGO) run --release -- serve --fleet --shards 1 --slow-query-ms 1000 --bind 127.0.0.1:0 --metrics-smoke
	$(CARGO) run --release -- cluster --backends 2 --shards 1 --bind 127.0.0.1:0 --metrics-smoke

# hybrid-parallelism profiler smoke, both tiers. Fleet: --profile-smoke
# arms the pool profiler on a live hybrid server, runs QUERYs against a
# net with real parallel work (hailfinder-sim), and asserts the PROFILE
# report shows non-zero busy lanes with imbalance inside [1, workers].
# Cluster: --profile-smoke turns on cluster-correlated tracing (front
# mints a qid per query, backends tag their span rings), replays one
# query's cross-tier timeline via TRACE q<n> (exactly one backend
# timeline), then merges every backend's PROFILE report.
profile-smoke:
	$(CARGO) run --release -- serve --fleet --engine hybrid --threads 2 --shards 1 --bind 127.0.0.1:0 --profile-smoke
	$(CARGO) run --release -- cluster --backends 2 --shards 1 --bind 127.0.0.1:0 --profile-smoke

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

fmt:
	$(CARGO) fmt --all

lint:
	$(CARGO) clippy --all-targets -- -D warnings

test-xla: artifacts
	$(CARGO) test -q --features xla

clean:
	$(CARGO) clean
	rm -rf artifacts
