//! Quickstart: load a classic network, ask diagnostic questions.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API surface once: parse BIF → compile the
//! junction tree → build an engine → set evidence → read posteriors.

use std::sync::Arc;

use fastbn::prelude::*;

fn main() -> Result<()> {
    // 1. A network. Embedded classics parse from BIF text; your own
    //    networks load with `fastbn::bn::bif::parse_file`.
    let net = fastbn::bn::embedded::asia();
    println!("network: {}", net.stats());

    // 2. Compile the junction tree once per network.
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    println!("junction tree: {}", jt.stats());

    // 3. Build the engine. `Hybrid` is Fast-BNI-par, the paper's
    //    contribution; see EngineKind for the five comparison engines.
    let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default());

    // 4. One reusable state per engine; reset happens inside infer().
    let mut state = TreeState::fresh(&jt);

    // Prior: how likely is lung cancer with no information?
    let prior = engine.infer(&mut state, &Evidence::none())?;
    println!("\nP(lung) prior               = {:.4}", prior.marginal(&net, "lung")?[0]);

    // A smoker walks in...
    let ev = Evidence::from_pairs(&net, &[("smoke", "yes")])?;
    let post = engine.infer(&mut state, &ev)?;
    println!("P(lung | smoke)             = {:.4}", post.marginal(&net, "lung")?[0]);

    // ...with a positive X-ray and dyspnoea.
    let ev = Evidence::from_pairs(&net, &[("smoke", "yes"), ("xray", "yes"), ("dysp", "yes")])?;
    let post = engine.infer(&mut state, &ev)?;
    println!("P(lung | smoke, xray, dysp) = {:.4}", post.marginal(&net, "lung")?[0]);
    println!("P(tub  | smoke, xray, dysp) = {:.4}", post.marginal(&net, "tub")?[0]);
    println!("P(e) = {:.6}", post.evidence_probability());

    // Impossible evidence is an error, not a NaN.
    let bad = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")])?;
    match engine.infer(&mut state, &bad) {
        Err(Error::InconsistentEvidence) => println!("\nimpossible evidence correctly rejected"),
        other => panic!("expected InconsistentEvidence, got {other:?}"),
    }
    Ok(())
}
