//! Streaming-evidence monitoring — inference as observations arrive.
//!
//! ```sh
//! cargo run --release --example sensor_stream
//! ```
//!
//! A Munin-style network (the paper's largest workloads are EMG
//! diagnostic networks, i.e. sensor interpretation) monitored live: each
//! tick delivers a new sensor reading, the engine re-infers, and we track
//! how the posterior of a target variable and ln P(e) evolve, plus
//! per-tick latency. Demonstrates state reuse across incremental
//! evidence — the serving pattern `fastbn serve` exposes over TCP.

use std::sync::Arc;
use std::time::Instant;

use fastbn::bn::netgen::NetSpec;
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::jt::evidence::Evidence;
use fastbn::jt::state::TreeState;
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;
use fastbn::rng::Rng;

fn main() -> fastbn::Result<()> {
    // a mid-size monitoring network (munin2-sim is heavier; this keeps the
    // example snappy while exercising the same code paths)
    let net = NetSpec {
        name: "plant-monitor".into(),
        nodes: 300,
        arcs: 420,
        max_parents: 3,
        card_choices: vec![(2, 0.5), (3, 0.3), (5, 0.2)],
        locality: 10,
        max_table: 1 << 13,
        alpha: 1.0,
        seed: 0x5E45,
    }
    .generate();
    println!("monitor model: {}", net.stats());
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    println!("junction tree: {}\n", jt.stats());

    let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default());
    let mut state = TreeState::fresh(&jt);

    // ground truth trajectory: a sampled world the sensors observe
    let mut rng = Rng::new(42);
    let world = fastbn::bn::sample::forward_sample(&net, &mut rng);
    let target = net.n() - 1; // "health" variable: last in topo order

    // sensors report in a random order, one per tick
    let mut sensor_order: Vec<usize> = (0..net.n() - 1).collect();
    rng.shuffle(&mut sensor_order);

    let mut obs: Vec<(usize, usize)> = Vec::new();
    println!(
        "{:>5} {:>10} {:>12} {:>14} {:>10}",
        "tick", "sensors", "P(target)", "ln P(e)", "latency"
    );
    let mut latencies = Vec::new();
    for (tick, &sensor) in sensor_order.iter().take(32).enumerate() {
        obs.push((sensor, world[sensor]));
        let ev = Evidence::from_ids(obs.clone());
        let t0 = Instant::now();
        let post = engine.infer(&mut state, &ev)?;
        let lat = t0.elapsed();
        latencies.push(lat);
        let p_true = post.probs[target][world[target]];
        if tick % 4 == 0 || tick == 31 {
            println!("{:>5} {:>10} {:>12.4} {:>14.3} {:>10.2?}", tick, ev.len(), p_true, post.log_z, lat);
        }
    }

    let summary = fastbn::coordinator::metrics::LatencySummary::from_samples(&latencies);
    println!("\nper-tick latency: {summary}");
    println!("(posterior of the true target state should trend toward certainty as sensors accumulate)");
    Ok(())
}
