//! End-to-end driver — the full system on the paper's evaluation
//! protocol, producing Table-1-style rows (recorded in EXPERIMENTS.md).
//!
//! ```sh
//! FASTBN_CASES=50 cargo run --release --example end_to_end
//! ```
//!
//! For each of the six Table-1 network analogs:
//!   1. generate the network (seeded) and compile its junction tree;
//!   2. generate evidence cases (20% observed, the paper's protocol);
//!   3. run the sequential comparison for real (UnBBayes-style naive
//!      baseline vs Fast-BNI-seq) and verify both agree case by case;
//!   4. run every *parallel* engine for real at the host's thread count
//!      (this container exposes one core — the run proves correctness
//!      and measures overheads) and through the calibrated cost model at
//!      t = 1..32 (the Table-1 "best t" protocol; DESIGN.md §3);
//!   5. exercise the XLA/PJRT path on the first network (all three
//!      layers composing on the request path).

use std::sync::Arc;
use std::time::Instant;

use fastbn::bench::{fmt_duration, print_table};
use fastbn::bn::netgen;
use fastbn::coordinator::{BatchConfig, BatchRunner};
use fastbn::engine::simulate::{best_over_threads, CostModel};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn main() -> fastbn::Result<()> {
    let n_cases: usize = std::env::var("FASTBN_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    let sweep = [1usize, 2, 4, 8, 16, 32];

    println!("fastbn end-to-end driver — Table 1 protocol on the synthetic analogs");
    println!("cases per network: {n_cases} (paper: 2000; override with FASTBN_CASES)");
    println!("calibrating the cost model for the parallel columns...");
    let model = CostModel::calibrate();
    println!("{model:?}\n");

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut first_net_done = false;

    for spec in netgen::paper_suite() {
        let t0 = Instant::now();
        let net = spec.generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
        eprintln!(
            "[{}] {} | JT: {} | compile {:?}",
            spec.name,
            net.stats(),
            jt.stats(),
            t0.elapsed()
        );
        let cases = generate(&net, &CaseSpec { n_cases, observed_fraction: 0.2, seed: 0xE2E });
        let runner = BatchRunner::new(Arc::clone(&jt));

        // --- sequential comparison (measured for real) ---
        let mut seq_results = Vec::new();
        for kind in [EngineKind::Unb, EngineKind::Seq] {
            let report = runner.run(
                &cases,
                &BatchConfig {
                    engine: kind,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 1,
                    fused_batch: 0,
                },
            )?;
            eprintln!(
                "  {:<13} {:>10} total | mean ln P(e) {:.4} | {} failures",
                report.engine,
                fmt_duration(report.wall),
                report.mean_log_z,
                report.failures.len()
            );
            seq_results.push(report);
        }
        let unb = &seq_results[0];
        let seq = &seq_results[1];
        assert!(
            (unb.mean_log_z - seq.mean_log_z).abs() < 1e-9,
            "sequential engines disagree on {}",
            spec.name
        );

        // --- parallel engines: real single-core run (correctness +
        //     overhead measurement) ---
        let mut real_par = Vec::new();
        for kind in EngineKind::PARALLEL {
            let report = runner.run(
                &cases,
                &BatchConfig {
                    engine: kind,
                    engine_cfg: EngineConfig::default().with_threads(2),
                    replicas: 1,
                    fused_batch: 0,
                },
            )?;
            assert!(
                (report.mean_log_z - seq.mean_log_z).abs() < 1e-9,
                "{kind} disagrees with seq on {}",
                spec.name
            );
            real_par.push(report);
        }

        // --- parallel comparison (modeled best-t, the Table-1 protocol) ---
        let cfg = EngineConfig::default();
        let mut modeled: Vec<(EngineKind, usize, f64)> = Vec::new();
        for kind in EngineKind::PARALLEL {
            let (t, per_case) = best_over_threads(kind, &jt, &sweep, &cfg, &model);
            modeled.push((kind, t, per_case * n_cases as f64));
        }
        let hybrid = modeled.iter().find(|(k, _, _)| *k == EngineKind::Hybrid).unwrap().2;

        rows.push(vec![
            spec.name.clone(),
            fmt_duration(unb.wall),
            fmt_duration(seq.wall),
            format!("{:.1}", unb.wall.as_secs_f64() / seq.wall.as_secs_f64()),
            format!("{:.2}s*", modeled[0].2),
            format!("{:.2}s*", modeled[1].2),
            format!("{:.2}s*", modeled[2].2),
            format!("{:.2}s*", hybrid),
            format!("{:.1}", modeled[0].2 / hybrid),
            format!("{:.1}", modeled[1].2 / hybrid),
            format!("{:.1}", modeled[2].2 / hybrid),
            format!("t={}", modeled[3].1),
        ]);

        // --- XLA/PJRT path on the first network (xla feature only) ---
        if !first_net_done {
            first_net_done = true;
            #[cfg(not(feature = "xla"))]
            eprintln!("  (xla feature disabled; skipping the XLA layer — rebuild with --features xla)");
            #[cfg(feature = "xla")]
            run_xla_path(&jt, &cases)?;
        }
    }

    print_table(
        &format!("Table 1 analog — {n_cases} cases, seq measured / par modeled best-t (*)"),
        &[
            "BN", "UnBBayes", "FastBNI-seq", "spd", "Dir.*", "Prim.*", "Elem.*", "FastBNI-par*", "spd-D",
            "spd-P", "spd-E", "best",
        ],
        &rows,
    );
    println!("\n(*) parallel columns are modeled via the calibrated critical-path cost");
    println!("    simulator (single-core container; DESIGN.md §3). Sequential columns and");
    println!("    all correctness checks are real measured runs.");
    Ok(())
}

/// Exercise the XLA/PJRT layer against the pure-Rust sequential engine.
#[cfg(feature = "xla")]
fn run_xla_path(
    jt: &Arc<JunctionTree>,
    cases: &[fastbn::jt::evidence::Evidence],
) -> fastbn::Result<()> {
    use fastbn::engine::Engine;
    let dir = fastbn::runtime::artifact_dir();
    if !fastbn::runtime::artifacts_available(&dir) {
        eprintln!("  (artifacts/ not built; skipping the XLA layer — run `make artifacts`)");
        return Ok(());
    }
    let mut accel = match fastbn::runtime::accel::SeqXlaEngine::new(
        Arc::clone(jt),
        &EngineConfig::default().with_threads(1),
        &dir,
        256,
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("  (XLA backend unavailable: {e}; skipping the XLA layer)");
            return Ok(());
        }
    };
    let mut state = fastbn::jt::state::TreeState::fresh(jt);
    let mut seq_engine = EngineKind::Seq.build(Arc::clone(jt), &EngineConfig::default().with_threads(1));
    let mut seq_state = fastbn::jt::state::TreeState::fresh(jt);
    let t0 = Instant::now();
    let mut worst = 0.0f64;
    for ev in cases.iter().take(5) {
        let a = accel.infer(&mut state, ev)?;
        let b = seq_engine.infer(&mut seq_state, ev)?;
        worst = worst.max(a.max_abs_diff(&b));
    }
    eprintln!(
        "  XLA/PJRT path: 5 cases in {:?}; {} ops via XLA, {} native; max |Δ| vs seq = {:.2e}",
        t0.elapsed(),
        accel.xla_ops,
        accel.native_ops,
        worst
    );
    assert!(worst < 1e-9, "XLA path diverged");
    Ok(())
}
