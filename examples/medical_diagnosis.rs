//! Medical-diagnosis batch workload — the scenario the paper's intro
//! motivates (Pathfinder was built for lymph-node pathology).
//!
//! ```sh
//! cargo run --release --example medical_diagnosis
//! ```
//!
//! Loads the Pathfinder-scale synthetic analog, generates a day's worth
//! of patient cases (20% of findings observed per patient, the paper's
//! protocol), runs them through the batch coordinator with two engines,
//! and prints the latency profile a deployment would monitor.

use std::sync::Arc;

use fastbn::bn::netgen;
use fastbn::coordinator::{BatchConfig, BatchRunner};
use fastbn::engine::{EngineConfig, EngineKind};
use fastbn::infer::cases::{generate, CaseSpec};
use fastbn::jt::tree::JunctionTree;
use fastbn::jt::triangulate::TriangulationHeuristic;

fn main() -> fastbn::Result<()> {
    let n_cases: usize = std::env::var("FASTBN_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(40);

    let net = netgen::paper_net("pathfinder-sim").expect("paper suite includes pathfinder-sim");
    println!("clinic model: {}", net.stats());
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    println!("compiled junction tree: {}", jt.stats());

    let cases = generate(&net, &CaseSpec { n_cases, observed_fraction: 0.2, seed: 0xD0C });
    println!("\ngenerated {n_cases} patient cases (20% of findings observed each)\n");

    let runner = BatchRunner::new(Arc::clone(&jt));
    for engine in [EngineKind::Seq, EngineKind::Hybrid] {
        let report = runner.run(
            &cases,
            &BatchConfig {
                engine,
                engine_cfg: EngineConfig::default(),
                replicas: 1,
                fused_batch: 0,
            },
        )?;
        println!(
            "{:<14} {:>8.2?} total | {:>7.1} cases/s | p50 {:>9.2?} p95 {:>9.2?} p99 {:>9.2?} | {} inconsistent",
            report.engine,
            report.wall,
            report.throughput(),
            report.latency.p50,
            report.latency.p95,
            report.latency.p99,
            report.failures.len(),
        );
    }

    // Drill into one patient: the posterior ranking a clinician would see.
    let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default());
    let mut state = fastbn::jt::state::TreeState::fresh(&jt);
    let post = engine.infer(&mut state, &cases[0])?;
    println!("\npatient 0: {} observations, ln P(e) = {:.3}", cases[0].len(), post.log_z);
    // top-5 most certain unobserved variables
    let mut ranked: Vec<(usize, f64)> = (0..net.n())
        .filter(|v| cases[0].get(*v).is_none())
        .map(|v| {
            let best = post.probs[v].iter().cloned().fold(0.0, f64::max);
            (v, best)
        })
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("most-certain unobserved findings:");
    for (v, p) in ranked.into_iter().take(5) {
        let s = post.probs[v].iter().position(|&x| x == p).unwrap();
        println!("  {:<10} -> {:<4} ({:.4})", net.vars[v].name, net.vars[v].states[s], p);
    }
    Ok(())
}
